//! High-performance layer-based HBM cache (paper §5.3, Fig 7).
//!
//! Each transformer layer owns an *isolated cache unit*: one contiguous
//! buffer sized for the activated-neuron count. The buffer layout is
//! `[slot, 3·d]` f32 (gate row | up row | down column per slot) plus a
//! per-slot activity mask, so the unit's storage is *directly* the FFN
//! kernel's weight operand — no gather copy on the compute path, which
//! is exactly the paper's "continuous memory ... directly used for
//! inference computation" design. Because the sparse-FFN reduction is
//! order-invariant, slot order never needs fixing up.
//!
//! The update policy is pluggable ([`HbmPolicy`]): the paper's ATU
//! (Adjacent Token Update) is the baseline; LRU and LLM-in-a-Flash's
//! sliding window are provided as comparators for the ablations. The
//! default is the set-associative + victim-buffer + way-predicted
//! organization in [`crate::cache::setassoc`], chosen by the
//! trace-driven policy sweep (`experiments cache_policy`).

use crate::precision::plan::LayerPlan;
use crate::precision::Dtype;
use std::collections::HashMap;

/// Residency key: the paper reloads a neuron when its *precision class*
/// changes, since the stored bytes differ per class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct NeuronAt {
    pub neuron: u32,
    pub dtype: Dtype,
}

/// Result of one cache update: what must be loaded (DRAM→HBM traffic)
/// and how much was reused.
#[derive(Debug, Clone, Default)]
pub struct UpdateResult {
    /// Neurons to fetch from DRAM, with target precision.
    pub load: Vec<NeuronAt>,
    /// Neurons evicted from the unit.
    pub evicted: usize,
    /// Plan entries already resident (cache hits).
    pub hits: usize,
    /// Hits served out of the victim buffer (set-associative
    /// organization only; zero for the flat policies).
    pub victim_hits: usize,
    /// Main-cache hits whose set's MRU way prediction was correct
    /// (set-associative organization only).
    pub way_hits: usize,
    /// Main-cache hits where a way prediction was consulted
    /// (set-associative organization only; `way_hits / way_lookups`
    /// is the prediction accuracy).
    pub way_lookups: usize,
}

/// One layer's isolated cache unit.
///
/// Residency is keyed by `(neuron, dtype)`: a batched step's *union
/// plan* may legitimately want the same neuron at two precisions (one
/// per co-resident session), and each precision is a distinct cache
/// entry with its own slot — the per-session kernel masks then select
/// each token's own copies, which is what keeps batched outputs
/// byte-identical to sequential ones. Single-token plans never produce
/// duplicate neurons, so the pre-batching behavior is unchanged.
#[derive(Debug)]
pub struct CacheUnit {
    /// Slot count (= activated-neuron budget of the layer).
    pub capacity: usize,
    /// f32 values per slot (3·d_model; 0 in simulated mode → no storage).
    pub values: usize,
    /// Contiguous `[capacity, values]` weight buffer (kernel operand).
    pub storage: Vec<f32>,
    /// Per-slot activity mask (kernel operand; 0.0 = dead slot).
    pub mask: Vec<f32>,
    resident: HashMap<NeuronAt, usize>,
    free: Vec<usize>,
    /// Monotone use counter for LRU bookkeeping.
    tick: u64,
    last_use: Vec<u64>,
}

impl CacheUnit {
    pub fn new(capacity: usize, values: usize) -> CacheUnit {
        CacheUnit {
            capacity,
            values,
            storage: vec![0.0; capacity * values],
            mask: vec![0.0; capacity],
            resident: HashMap::with_capacity(capacity),
            free: (0..capacity).rev().collect(),
            tick: 0,
            last_use: vec![0; capacity],
        }
    }

    /// Simulated-mode unit: tracks residency but stores no data.
    pub fn meta_only(capacity: usize) -> CacheUnit {
        CacheUnit::new(capacity, 0)
    }

    pub fn len(&self) -> usize {
        self.resident.len()
    }

    pub fn is_empty(&self) -> bool {
        self.resident.is_empty()
    }

    pub fn contains(&self, neuron: u32, dtype: Dtype) -> bool {
        self.resident.contains_key(&NeuronAt { neuron, dtype })
    }

    /// The precision a neuron is resident at, or `None`. When a batched
    /// union left several precision copies resident, the highest
    /// precision is reported (`Dtype` declaration order). O(1): probes
    /// the four possible `(neuron, dtype)` keys instead of scanning
    /// residents — this sits in every policy's per-entry miss path.
    pub fn dtype_of(&self, neuron: u32) -> Option<Dtype> {
        Dtype::ALL
            .iter()
            .copied()
            .find(|&dtype| self.resident.contains_key(&NeuronAt { neuron, dtype }))
    }

    /// Every precision copy of `neuron` currently resident (sorted by
    /// precision, highest first). O(1) via the same key probes as
    /// [`dtype_of`].
    pub fn copies_of(&self, neuron: u32) -> Vec<NeuronAt> {
        Dtype::ALL
            .iter()
            .map(|&dtype| NeuronAt { neuron, dtype })
            .filter(|na| self.resident.contains_key(na))
            .collect()
    }

    /// Insert a neuron's dequantized values (len must equal `values`).
    /// Returns the slot. Panics if full — policies must evict first.
    pub fn insert(&mut self, neuron: u32, dtype: Dtype, data: &[f32]) -> usize {
        let na = NeuronAt { neuron, dtype };
        assert!(
            !self.resident.contains_key(&na),
            "neuron {neuron} already resident at {dtype:?}; evict before re-insert"
        );
        let slot = self.free.pop().expect("cache unit full");
        if self.values > 0 {
            assert_eq!(data.len(), self.values, "record length mismatch");
            self.storage[slot * self.values..(slot + 1) * self.values]
                .copy_from_slice(data);
        }
        self.mask[slot] = 1.0;
        self.tick += 1;
        self.last_use[slot] = self.tick;
        self.resident.insert(na, slot);
        slot
    }

    /// Remove every precision copy of a neuron; slots are masked dead
    /// (no memset needed — the kernel's mask kills the contribution,
    /// the paper's "management overhead is nearly zero" property).
    pub fn evict(&mut self, neuron: u32) -> bool {
        let copies = self.copies_of(neuron);
        for na in &copies {
            self.evict_at(*na);
        }
        !copies.is_empty()
    }

    /// Remove one `(neuron, dtype)` entry.
    pub fn evict_at(&mut self, na: NeuronAt) -> bool {
        if let Some(slot) = self.resident.remove(&na) {
            self.mask[slot] = 0.0;
            self.free.push(slot);
            true
        } else {
            false
        }
    }

    /// Slot index of a resident neuron (highest-precision copy when a
    /// union left several).
    pub fn slot_of(&self, neuron: u32) -> Option<usize> {
        self.dtype_of(neuron)
            .and_then(|dtype| self.resident.get(&NeuronAt { neuron, dtype }).copied())
    }

    /// Slot index of one exact `(neuron, dtype)` entry — the mask-build
    /// lookup of the batched forward path.
    pub fn slot_at(&self, na: NeuronAt) -> Option<usize> {
        self.resident.get(&na).copied()
    }

    /// Mark a resident neuron as used now (for LRU): every precision
    /// copy is stamped with the advanced clock.
    pub fn touch(&mut self, neuron: u32) {
        let copies = self.copies_of(neuron);
        if copies.is_empty() {
            return;
        }
        self.tick += 1;
        for na in copies {
            let slot = self.resident[&na];
            self.last_use[slot] = self.tick;
        }
    }

    /// Mark one exact `(neuron, dtype)` entry as used now.
    pub fn touch_at(&mut self, na: NeuronAt) {
        if let Some(&slot) = self.resident.get(&na) {
            self.tick += 1;
            self.last_use[slot] = self.tick;
        }
    }

    /// Least-recently-used resident neuron, if any.
    pub fn lru_victim(&self) -> Option<u32> {
        self.resident
            .iter()
            .min_by_key(|(na, slot)| (self.last_use[**slot], na.neuron, na.dtype))
            .map(|(na, _)| na.neuron)
    }

    pub fn resident_neurons(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self.resident.keys().map(|na| na.neuron).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Every resident `(neuron, dtype)` entry, sorted.
    pub fn resident_entries(&self) -> Vec<NeuronAt> {
        let mut v: Vec<NeuronAt> = self.resident.keys().copied().collect();
        v.sort_by_key(|na| (na.neuron, na.dtype));
        v
    }

    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    pub fn clear(&mut self) {
        self.resident.clear();
        self.free = (0..self.capacity).rev().collect();
        self.mask.fill(0.0);
        // Reset the use clock too: a cleared unit must not leak
        // pre-clear recency stamps into post-clear LRU ordering (a
        // fresh insert would otherwise look *older* than a stale slot).
        self.tick = 0;
        self.last_use.fill(0);
    }

    /// HBM bytes held by this unit's buffer (the capacity reservation,
    /// as units are fixed contiguous allocations).
    pub fn reserved_bytes(&self) -> u64 {
        (self.capacity * self.values * 4 + self.capacity * 4) as u64
    }
}

/// Pluggable update policy (paper §5.3 "Cache Policy").
pub trait HbmPolicy {
    /// Reconcile the unit with the new plan. Must leave every planned
    /// neuron either resident or listed in `UpdateResult::load` (the
    /// engine inserts loaded data afterwards via [`CacheUnit::insert`]).
    fn update(&mut self, unit: &mut CacheUnit, plan: &LayerPlan) -> UpdateResult;
    fn name(&self) -> &'static str;
}

/// Adjacent Token Update: evict exactly the residents that the new plan
/// no longer wants; load exactly the planned neurons not resident at the
/// right precision. No popularity tracking — the paper's measured ~80 %
/// token-to-token overlap does the work.
#[derive(Debug, Default, Clone)]
pub struct AtuPolicy;

impl HbmPolicy for AtuPolicy {
    fn update(&mut self, unit: &mut CacheUnit, plan: &LayerPlan) -> UpdateResult {
        // Wanted entries are exact (neuron, dtype) pairs: a batched
        // union plan may want the same neuron at two precisions, and
        // both are kept. Single-token plans degenerate to the original
        // one-dtype-per-neuron diff.
        let wanted: std::collections::HashSet<NeuronAt> = plan
            .iter()
            .map(|(neuron, dtype)| NeuronAt { neuron, dtype })
            .collect();
        // Evict residents that are unplanned or precision-stale.
        let stale: Vec<NeuronAt> = unit
            .resident
            .keys()
            .filter(|na| !wanted.contains(na))
            .copied()
            .collect();
        let evicted = stale.len();
        for na in stale {
            unit.evict_at(na);
        }
        // Remaining residents are hits (each union entry counted once);
        // the rest must load.
        let mut load = Vec::new();
        let mut hits = 0;
        for &na in &wanted {
            if unit.slot_at(na).is_some() {
                unit.touch_at(na);
                hits += 1;
            } else {
                load.push(na);
            }
        }
        load.sort_by_key(|na| (na.neuron, na.dtype));
        UpdateResult { load, evicted, hits, ..Default::default() }
    }

    fn name(&self) -> &'static str {
        "atu"
    }
}

/// Classic LRU over a unit whose capacity exceeds the per-token active
/// count: planned-but-missing neurons load; evictions only happen when
/// slots run out, preferring the least recently used resident. Models
/// the "dynamic cache designs ... high overhead" comparator of §5.3.
#[derive(Debug, Default, Clone)]
pub struct LruPolicy;

impl HbmPolicy for LruPolicy {
    fn update(&mut self, unit: &mut CacheUnit, plan: &LayerPlan) -> UpdateResult {
        let mut load: Vec<NeuronAt> = Vec::new();
        let mut hits = 0;
        let mut evicted = 0;
        let wanted: std::collections::HashSet<NeuronAt> = plan
            .iter()
            .map(|(neuron, dtype)| NeuronAt { neuron, dtype })
            .collect();
        for (n, dt) in plan.iter() {
            let na = NeuronAt { neuron: n, dtype: dt };
            if unit.slot_at(na).is_some() {
                unit.touch_at(na);
                hits += 1;
                continue;
            }
            // Precision-stale copies must reload — but only copies this
            // plan does not *also* want (a union plan keeps both).
            for copy in unit.copies_of(n) {
                if !wanted.contains(&copy) {
                    unit.evict_at(copy);
                    evicted += 1;
                }
            }
            // The engine inserts `load` only after this update returns,
            // so slots already promised to earlier loads count as used.
            if unit.free_slots() <= load.len() {
                // Evict LRU victims that are NOT wanted entries — the
                // exact (neuron, dtype) set, so a leftover extra
                // precision copy of a planned neuron (a prior batched
                // union wanted it) is still a legal victim.
                let victim = unit
                    .resident
                    .iter()
                    .filter(|(na, _)| !wanted.contains(na))
                    .min_by_key(|(na, slot)| (unit.last_use[**slot], na.neuron, na.dtype))
                    .map(|(na, _)| *na);
                match victim {
                    Some(v) => {
                        unit.evict_at(v);
                        evicted += 1;
                    }
                    None => panic!("LRU cache smaller than plan"),
                }
            }
            load.push(na);
        }
        load.sort_by_key(|na| (na.neuron, na.dtype));
        UpdateResult { load, evicted, hits, ..Default::default() }
    }

    fn name(&self) -> &'static str {
        "lru"
    }
}

/// LLM-in-a-Flash's sliding window: keep the union of the last `window`
/// plans resident; evict neurons that age out.
#[derive(Debug, Clone)]
pub struct SlidingWindowPolicy {
    pub window: usize,
    history: std::collections::VecDeque<Vec<NeuronAt>>,
}

impl SlidingWindowPolicy {
    pub fn new(window: usize) -> SlidingWindowPolicy {
        assert!(window >= 1);
        SlidingWindowPolicy {
            window,
            history: Default::default(),
        }
    }
}

impl HbmPolicy for SlidingWindowPolicy {
    fn update(&mut self, unit: &mut CacheUnit, plan: &LayerPlan) -> UpdateResult {
        let entries: Vec<NeuronAt> = plan
            .iter()
            .map(|(neuron, dtype)| NeuronAt { neuron, dtype })
            .collect();
        self.history.push_back(entries);
        if self.history.len() > self.window {
            self.history.pop_front();
        }
        let keep: std::collections::HashSet<NeuronAt> =
            self.history.iter().flatten().copied().collect();
        let aged: Vec<NeuronAt> = unit
            .resident
            .keys()
            .filter(|na| !keep.contains(na))
            .copied()
            .collect();
        let mut evicted = aged.len();
        for na in aged {
            unit.evict_at(na);
        }
        let mut load: Vec<NeuronAt> = Vec::new();
        let mut hits = 0;
        let wanted: std::collections::HashSet<NeuronAt> = plan
            .iter()
            .map(|(neuron, dtype)| NeuronAt { neuron, dtype })
            .collect();
        for (n, dt) in plan.iter() {
            let na = NeuronAt { neuron: n, dtype: dt };
            if unit.slot_at(na).is_some() {
                unit.touch_at(na);
                hits += 1;
            } else {
                // Precision-stale copies reload unless the (union) plan
                // also wants them at their current precision.
                for copy in unit.copies_of(n) {
                    if !wanted.contains(&copy) {
                        unit.evict_at(copy);
                        evicted += 1;
                    }
                }
                // Deferred inserts: slots promised to earlier loads
                // count as used (see LruPolicy).
                if unit.free_slots() <= load.len() {
                    // Window too wide for the unit: drop non-wanted
                    // extras (exact (neuron, dtype) entries, so leftover
                    // union precision copies of planned neurons stay
                    // legal victims), lowest key first.
                    let victim = unit
                        .resident
                        .keys()
                        .filter(|na| !wanted.contains(na))
                        .min_by_key(|na| (na.neuron, na.dtype))
                        .copied()
                        .expect("sliding window smaller than plan");
                    unit.evict_at(victim);
                    evicted += 1;
                }
                load.push(na);
            }
        }
        load.sort_by_key(|na| (na.neuron, na.dtype));
        UpdateResult { load, evicted, hits, ..Default::default() }
    }

    fn name(&self) -> &'static str {
        "sliding_window"
    }
}

/// Merge per-session plans into their `(neuron, dtype)` union — the
/// single reconciliation target of one batched step. A neuron wanted at
/// two precisions appears once per precision (each is a distinct cache
/// entry the per-session kernel masks select independently). Class
/// lists come out sorted and deduped, so equal unions compare equal and
/// the derived load lists are deterministic. Takes any iterator of plan
/// refs so per-layer hot loops feed lane subsets without cloning.
pub fn union_plans<'a, I>(plans: I) -> LayerPlan
where
    I: IntoIterator<Item = &'a LayerPlan>,
{
    let mut union = LayerPlan::default();
    for p in plans {
        union.fp16.extend_from_slice(&p.fp16);
        union.int8.extend_from_slice(&p.int8);
        union.int4.extend_from_slice(&p.int4);
    }
    for class in [&mut union.fp16, &mut union.int8, &mut union.int4] {
        class.sort_unstable();
        class.dedup();
    }
    union
}

/// Greedily partition batch lanes into groups whose combined
/// `(neuron, dtype)` union fits a cache unit of `capacity` slots.
/// Returns lane-index groups in order; a single lane always forms a
/// legal group (per-token plans never exceed the unit, which is sized
/// for at least one plan). Only low-overlap batches ever split — at the
/// paper's ~80 % token-to-token overlap the union of a whole batch
/// stays far below `sessions × plan`.
pub fn partition_by_union(plans: &[LayerPlan], capacity: usize) -> Vec<Vec<usize>> {
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = Vec::new();
    let mut current_union: std::collections::HashSet<NeuronAt> =
        std::collections::HashSet::new();
    for (i, plan) in plans.iter().enumerate() {
        let fresh: Vec<NeuronAt> = plan
            .iter()
            .map(|(neuron, dtype)| NeuronAt { neuron, dtype })
            .filter(|na| !current_union.contains(na))
            .collect();
        if !current.is_empty() && current_union.len() + fresh.len() > capacity {
            groups.push(std::mem::take(&mut current));
            current_union.clear();
            current_union.extend(
                plan.iter()
                    .map(|(neuron, dtype)| NeuronAt { neuron, dtype }),
            );
        } else {
            current_union.extend(fresh);
        }
        current.push(i);
    }
    if !current.is_empty() {
        groups.push(current);
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::plan::{plan_from_scores, PrecisionRatios};
    use crate::util::check::Check;
    use crate::util::rng::Rng;

    fn plan_of(fp16: &[u32], int8: &[u32], int4: &[u32]) -> LayerPlan {
        LayerPlan {
            fp16: fp16.to_vec(),
            int8: int8.to_vec(),
            int4: int4.to_vec(),
        }
    }

    #[test]
    fn insert_evict_roundtrip_with_storage() {
        let mut u = CacheUnit::new(4, 3);
        let s = u.insert(7, Dtype::F16, &[1.0, 2.0, 3.0]);
        assert!(u.contains(7, Dtype::F16));
        assert!(!u.contains(7, Dtype::Int8), "dtype is part of the key");
        assert_eq!(u.mask[s], 1.0);
        assert_eq!(&u.storage[s * 3..s * 3 + 3], &[1.0, 2.0, 3.0]);
        assert!(u.evict(7));
        assert_eq!(u.mask[s], 0.0);
        assert!(!u.evict(7), "double evict is a no-op");
    }

    #[test]
    #[should_panic(expected = "full")]
    fn insert_past_capacity_panics() {
        let mut u = CacheUnit::meta_only(1);
        u.insert(0, Dtype::Int8, &[]);
        u.insert(1, Dtype::Int8, &[]);
    }

    #[test]
    fn atu_loads_everything_on_cold_start() {
        let mut u = CacheUnit::meta_only(8);
        let plan = plan_of(&[1, 2], &[3], &[4, 5]);
        let r = AtuPolicy.update(&mut u, &plan);
        assert_eq!(r.hits, 0);
        assert_eq!(r.load.len(), 5);
        assert_eq!(r.evicted, 0);
    }

    #[test]
    fn atu_diff_is_exact_set_difference() {
        let mut u = CacheUnit::meta_only(8);
        let p1 = plan_of(&[1, 2], &[3, 4], &[]);
        let r1 = AtuPolicy.update(&mut u, &p1);
        for na in &r1.load {
            u.insert(na.neuron, na.dtype, &[]);
        }
        // Next token: 2,3 persist at same precision; 1 changes precision
        // (fp16 -> int8) => reload; 4 dropped; 9 fresh.
        let p2 = plan_of(&[2], &[3, 1], &[9]);
        let r2 = AtuPolicy.update(&mut u, &p2);
        assert_eq!(r2.hits, 2, "2@fp16 and 3@int8 reused");
        let loads: Vec<u32> = r2.load.iter().map(|n| n.neuron).collect();
        assert_eq!(loads, vec![1, 9]);
        assert_eq!(r2.evicted, 2, "4 dropped + 1 precision-stale");
    }

    #[test]
    fn atu_hit_ratio_tracks_overlap() {
        // With an 80%-overlap trace, steady-state hit ratio ≈ 80% (Fig 6
        // -> paper's claimed ~80% ATU hit ratio).
        use crate::sparsity::trace::{ActivationTrace, TraceConfig};
        let cfg = TraceConfig {
            n_neurons: 500,
            active: 100,
            overlap: 0.8,
            zipf_s: 1.0,
        };
        let mut trace = ActivationTrace::new(cfg, 3);
        let mut u = CacheUnit::meta_only(100);
        let mut pol = AtuPolicy;
        let ratios = PrecisionRatios::new(1.0, 0.0, 0.0);
        let (mut hits, mut total) = (0usize, 0usize);
        for t in 0..60 {
            let (ids, _) = trace.next_token();
            // Build a plan over the full neuron population scores.
            let mut scores = vec![f32::NEG_INFINITY; 500];
            for (rank, &id) in ids.iter().enumerate() {
                scores[id as usize] = 1000.0 - rank as f32;
            }
            let plan = plan_from_scores(&scores, &PrecisionRatios::new(0.2, 0.0, 0.0));
            let _ = ratios;
            let r = pol.update(&mut u, &plan);
            for na in &r.load {
                u.insert(na.neuron, na.dtype, &[]);
            }
            if t >= 10 {
                hits += r.hits;
                total += plan.total_active();
            }
        }
        let ratio = hits as f64 / total as f64;
        assert!(
            (0.70..0.95).contains(&ratio),
            "steady-state ATU hit ratio {ratio:.2} (paper ~0.8)"
        );
    }

    #[test]
    fn lru_keeps_extras_until_pressure() {
        let mut u = CacheUnit::meta_only(4);
        let mut pol = LruPolicy;
        let p1 = plan_of(&[1, 2], &[], &[]);
        let r1 = pol.update(&mut u, &p1);
        for na in &r1.load {
            u.insert(na.neuron, na.dtype, &[]);
        }
        // Plan moves on to 3,4 — with capacity 4, 1 and 2 stay cached.
        let p2 = plan_of(&[3, 4], &[], &[]);
        let r2 = pol.update(&mut u, &p2);
        for na in &r2.load {
            u.insert(na.neuron, na.dtype, &[]);
        }
        assert_eq!(u.len(), 4);
        // Plan returns to 1,2: all hits, unlike ATU which would reload.
        let p3 = plan_of(&[1, 2], &[], &[]);
        let r3 = pol.update(&mut u, &p3);
        assert_eq!(r3.hits, 2);
        assert!(r3.load.is_empty());
    }

    #[test]
    fn lru_evicts_least_recently_used_under_pressure() {
        let mut u = CacheUnit::meta_only(2);
        let mut pol = LruPolicy;
        for p in [plan_of(&[1], &[], &[]), plan_of(&[2], &[], &[])] {
            for na in pol.update(&mut u, &p).load {
                u.insert(na.neuron, na.dtype, &[]);
            }
        }
        // Touch 1 again, then insert 3 => victim must be 2.
        let _ = pol.update(&mut u, &plan_of(&[1], &[], &[]));
        let r = pol.update(&mut u, &plan_of(&[3], &[], &[]));
        for na in r.load {
            u.insert(na.neuron, na.dtype, &[]);
        }
        assert!(u.contains(1, Dtype::F16));
        assert!(u.contains(3, Dtype::F16));
        assert!(u.dtype_of(2).is_none());
    }

    #[test]
    fn sliding_window_ages_out() {
        let mut u = CacheUnit::meta_only(8);
        let mut pol = SlidingWindowPolicy::new(2);
        for p in [
            plan_of(&[1], &[], &[]),
            plan_of(&[2], &[], &[]),
            plan_of(&[3], &[], &[]),
        ] {
            for na in pol.update(&mut u, &p).load {
                u.insert(na.neuron, na.dtype, &[]);
            }
        }
        // Window 2 keeps {2,3}; 1 aged out.
        assert!(u.dtype_of(1).is_none());
        assert!(u.dtype_of(2).is_some());
        assert!(u.dtype_of(3).is_some());
    }

    #[test]
    fn policies_leave_plan_fully_serviceable() {
        // Property: after update + inserting all loads, every planned
        // neuron is resident at the planned precision — for all policies.
        Check::new(48, 0xCAC4E).run("plan serviceable", |rng| {
            let n = 64usize;
            let mut unit = CacheUnit::meta_only(n);
            let mut policies: Vec<Box<dyn HbmPolicy>> = vec![
                Box::new(AtuPolicy),
                Box::new(LruPolicy),
                Box::new(SlidingWindowPolicy::new(3)),
                Box::new(crate::cache::SetAssocPolicy::new(4, 8)),
                Box::new(crate::cache::SetAssocPolicy::new(8, 0)),
            ];
            let pol = &mut policies[rng.range(0, 5)];
            for _ in 0..8 {
                let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
                let plan =
                    plan_from_scores(&scores, &PrecisionRatios::new(0.1, 0.1, 0.2));
                let r = pol.update(&mut unit, &plan);
                for na in &r.load {
                    unit.insert(na.neuron, na.dtype, &[]);
                }
                for (neuron, dt) in plan.iter() {
                    if !unit.contains(neuron, dt) {
                        return Err(format!(
                            "{}: neuron {neuron} not serviceable at {:?}",
                            pol.name(),
                            dt
                        ));
                    }
                }
                if r.hits + r.load.len() != plan.total_active() {
                    return Err(format!(
                        "{}: hits {} + loads {} != plan {}",
                        pol.name(),
                        r.hits,
                        r.load.len(),
                        plan.total_active()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cache_unit_invariants_under_random_ops() {
        // Property sweep over random insert/evict/touch sequences:
        //   1. slot conservation: residents + free slots == capacity
        //   2. mask agrees with residency (per-slot and in total)
        //   3. no slot is assigned to two neurons
        //   4. the use clock is monotone and `last_use` never runs ahead
        //      of it; a just-touched resident holds the newest stamp
        //   5. lru_victim is exactly the min-(last_use, id) resident
        Check::new(64, 0x51075).run("cache unit invariants", |rng| {
            let cap = rng.range(1, 24);
            let mut u = CacheUnit::meta_only(cap);
            for op in 0..64 {
                let neuron = rng.below(32) as u32;
                let mut prev_tick = u.tick;
                match rng.range(0, 5) {
                    0 => {
                        if u.free_slots() > 0 && u.dtype_of(neuron).is_none() {
                            let dt = [Dtype::F16, Dtype::Int8, Dtype::Int4]
                                [rng.range(0, 3)];
                            let slot = u.insert(neuron, dt, &[]);
                            if u.last_use[slot] != u.tick {
                                return Err(format!(
                                    "op {op}: insert did not stamp last_use"
                                ));
                            }
                        }
                    }
                    1 => {
                        let was = u.dtype_of(neuron).is_some();
                        if u.evict(neuron) != was {
                            return Err(format!("op {op}: evict return mismatch"));
                        }
                    }
                    2 => {
                        let resident = u.dtype_of(neuron).is_some();
                        u.touch(neuron);
                        if resident {
                            let slot = u.slot_of(neuron).unwrap();
                            if u.last_use[slot] != u.tick || u.tick != prev_tick + 1 {
                                return Err(format!(
                                    "op {op}: touch did not advance the clock"
                                ));
                            }
                        } else if u.tick != prev_tick {
                            return Err(format!("op {op}: touch of absent advanced clock"));
                        }
                    }
                    3 => {
                        // clear() must forget residency AND recency: a
                        // stale clock would make post-clear inserts look
                        // older than pre-clear slots ever were.
                        u.clear();
                        if u.tick != 0 || u.last_use.iter().any(|&t| t != 0) {
                            return Err(format!(
                                "op {op}: clear left recency stamps behind"
                            ));
                        }
                        if u.len() != 0 || u.free_slots() != cap {
                            return Err(format!("op {op}: clear left residents"));
                        }
                        prev_tick = 0; // the clock legitimately restarts
                    }
                    _ => {} // no-op round: re-check invariants only
                }
                if u.tick < prev_tick {
                    return Err(format!("op {op}: use clock went backwards"));
                }
                // 1. Conservation.
                if u.len() + u.free_slots() != cap {
                    return Err(format!(
                        "op {op}: {} resident + {} free != {cap}",
                        u.len(),
                        u.free_slots()
                    ));
                }
                // 2 + 3. Mask/residency agreement, slot uniqueness.
                let residents = u.resident_neurons();
                let mut slots: Vec<usize> = Vec::with_capacity(residents.len());
                for &n in &residents {
                    let slot = u.slot_of(n).ok_or_else(|| {
                        format!("op {op}: resident {n} has no slot")
                    })?;
                    if u.mask[slot] != 1.0 {
                        return Err(format!("op {op}: live slot {slot} masked dead"));
                    }
                    if u.last_use[slot] > u.tick {
                        return Err(format!("op {op}: last_use ahead of clock"));
                    }
                    slots.push(slot);
                }
                slots.sort_unstable();
                slots.dedup();
                if slots.len() != residents.len() {
                    return Err(format!("op {op}: slot double-assignment"));
                }
                let live_mask = u.mask.iter().filter(|&&m| m == 1.0).count();
                if live_mask != residents.len() {
                    return Err(format!(
                        "op {op}: {live_mask} live mask slots vs {} residents",
                        residents.len()
                    ));
                }
                // 5. LRU victim is the stalest resident.
                let expect = residents
                    .iter()
                    .map(|&n| (u.last_use[u.slot_of(n).unwrap()], n))
                    .min();
                if u.lru_victim() != expect.map(|(_, n)| n) {
                    return Err(format!("op {op}: lru_victim not the stalest"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn atu_and_lru_agree_when_capacity_equals_plan() {
        // With the unit sized exactly to the per-token plan, LRU's slack
        // disappears: both policies must end each update holding exactly
        // the plan, with identical hit counts and identical load
        // multisets for the identical plan sequence. (The policies only
        // diverge when capacity exceeds the plan — LRU keeps extras.)
        Check::new(48, 0xA7B1).run("atu == lru at exact capacity", |rng| {
            let n = 60usize;
            let ratios = PrecisionRatios::new(0.1, 0.1, 0.2); // plan = 24
            let mut ua = CacheUnit::meta_only(24);
            let mut ul = CacheUnit::meta_only(24);
            let mut pa = AtuPolicy;
            let mut pl = LruPolicy;
            for step in 0..12 {
                let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
                let plan = plan_from_scores(&scores, &ratios);
                let ra = pa.update(&mut ua, &plan);
                for na in &ra.load {
                    ua.insert(na.neuron, na.dtype, &[]);
                }
                let rl = pl.update(&mut ul, &plan);
                for na in &rl.load {
                    ul.insert(na.neuron, na.dtype, &[]);
                }
                if ra.hits != rl.hits {
                    return Err(format!(
                        "step {step}: atu {} hits vs lru {}",
                        ra.hits, rl.hits
                    ));
                }
                // Loads are returned neuron-sorted by both policies, so
                // multiset equality is plain equality.
                if ra.load != rl.load {
                    return Err(format!(
                        "step {step}: load sets differ ({} vs {})",
                        ra.load.len(),
                        rl.load.len()
                    ));
                }
                if ua.resident_neurons() != ul.resident_neurons() {
                    return Err(format!("step {step}: residency diverged"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn slot_of_tracks_residency() {
        let mut u = CacheUnit::meta_only(2);
        assert_eq!(u.slot_of(5), None);
        let s = u.insert(5, Dtype::F16, &[]);
        assert_eq!(u.slot_of(5), Some(s));
        u.evict(5);
        assert_eq!(u.slot_of(5), None);
    }

    #[test]
    fn reserved_bytes_accounting() {
        let u = CacheUnit::new(10, 384);
        assert_eq!(u.reserved_bytes(), (10 * 384 * 4 + 40) as u64);
    }

    #[test]
    fn union_merges_and_keeps_dtype_conflicts() {
        let a = plan_of(&[1, 2], &[3], &[]);
        let b = plan_of(&[2, 5], &[1], &[7]);
        let u = union_plans(&[a, b]);
        assert_eq!(u.fp16, vec![1, 2, 5]);
        // Neuron 1 is wanted at fp16 (session a) AND int8 (session b):
        // both survive as distinct entries.
        assert_eq!(u.int8, vec![1, 3]);
        assert_eq!(u.int4, vec![7]);
        assert_eq!(u.total_active(), 6);
    }

    #[test]
    fn unit_holds_two_precision_copies_of_one_neuron() {
        let mut u = CacheUnit::new(4, 2);
        let s16 = u.insert(9, Dtype::F16, &[1.0, 2.0]);
        let s8 = u.insert(9, Dtype::Int8, &[3.0, 4.0]);
        assert_ne!(s16, s8);
        assert_eq!(u.slot_at(NeuronAt { neuron: 9, dtype: Dtype::F16 }), Some(s16));
        assert_eq!(u.slot_at(NeuronAt { neuron: 9, dtype: Dtype::Int8 }), Some(s8));
        // dtype_of/slot_of report the highest-precision copy.
        assert_eq!(u.dtype_of(9), Some(Dtype::F16));
        assert_eq!(u.slot_of(9), Some(s16));
        assert_eq!(u.len(), 2);
        assert_eq!(u.resident_neurons(), vec![9]);
        // evict removes both copies.
        assert!(u.evict(9));
        assert_eq!(u.len(), 0);
        assert_eq!(u.free_slots(), 4);
    }

    #[test]
    fn lru_evicts_union_leftover_copies_under_pressure() {
        // Regression: after a batched union left {1, 2} resident at TWO
        // precisions each, a full unit plus a plan wanting a fresh
        // neuron used to panic ("LRU cache smaller than plan") because
        // victim selection spared every copy of a planned *neuron*,
        // including the extra-precision leftovers the plan does not
        // want. Those exact entries must be legal victims.
        let mut u = CacheUnit::meta_only(4);
        let union = plan_of(&[1, 2], &[1, 2], &[]);
        let mut pol = LruPolicy;
        for na in pol.update(&mut u, &union).load {
            u.insert(na.neuron, na.dtype, &[]);
        }
        assert_eq!(u.len(), 4);
        assert_eq!(u.free_slots(), 0);
        let plan = plan_of(&[1, 2, 3], &[], &[]);
        let r = pol.update(&mut u, &plan);
        assert_eq!(r.hits, 2, "1@fp16 and 2@fp16 stay hits");
        assert_eq!(
            r.load,
            vec![NeuronAt { neuron: 3, dtype: Dtype::F16 }],
            "only the fresh neuron loads"
        );
        assert!(r.evicted >= 1, "an int8 leftover must have made room");
        u.insert(3, Dtype::F16, &[]);
        for (n, dt) in plan.iter() {
            assert!(u.contains(n, dt), "plan entry {n}@{dt:?} serviceable");
        }
    }

    #[test]
    fn union_reconciliation_loads_once_and_serves_every_session() {
        // The batched-step contract: reconciling ONCE against the union
        // must (a) cost no more loads than reconciling per session on
        // an identically warmed unit, (b) count each union entry at
        // most once (hits + loads == union size), and (c) leave every
        // per-session plan fully serviceable at its own precision.
        Check::new(48, 0xBA7C4).run("union reconciliation", |rng| {
            let n = 48usize;
            let ratios = PrecisionRatios::new(0.1, 0.1, 0.2);
            let warm = plan_from_scores(
                &(0..n).map(|_| rng.f32()).collect::<Vec<f32>>(),
                &ratios,
            );
            let mut seq_unit = CacheUnit::meta_only(n * 3);
            let mut uni_unit = CacheUnit::meta_only(n * 3);
            for unit in [&mut seq_unit, &mut uni_unit] {
                for na in AtuPolicy.update(unit, &warm).load {
                    unit.insert(na.neuron, na.dtype, &[]);
                }
            }
            // A batch of per-session plans for the next step.
            let b = rng.range(2, 6);
            let plans: Vec<LayerPlan> = (0..b)
                .map(|_| {
                    plan_from_scores(
                        &(0..n).map(|_| rng.f32()).collect::<Vec<f32>>(),
                        &ratios,
                    )
                })
                .collect();
            // Sequential: one ATU reconcile per session (what N separate
            // forwards would do); each session's loads accumulate.
            let mut seq_loads = 0usize;
            for p in &plans {
                let r = AtuPolicy.update(&mut seq_unit, p);
                seq_loads += r.load.len();
                for na in r.load {
                    seq_unit.insert(na.neuron, na.dtype, &[]);
                }
            }
            // Batched: one reconcile against the union.
            let union = union_plans(&plans);
            let r = AtuPolicy.update(&mut uni_unit, &union);
            if r.load.len() > seq_loads {
                return Err(format!(
                    "union loaded {} entries, sequential only {}",
                    r.load.len(),
                    seq_loads
                ));
            }
            if r.hits + r.load.len() != union.total_active() {
                return Err(format!(
                    "hits {} + loads {} != union {} (entries double-counted)",
                    r.hits,
                    r.load.len(),
                    union.total_active()
                ));
            }
            for na in r.load {
                uni_unit.insert(na.neuron, na.dtype, &[]);
            }
            for p in &plans {
                for (neuron, dt) in p.iter() {
                    if uni_unit.slot_at(NeuronAt { neuron, dtype: dt }).is_none() {
                        return Err(format!(
                            "session plan entry {neuron}@{dt:?} not serviceable after union update"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sliding_window_state_must_not_alias_across_layers() {
        // Headline regression: ExecEngine/SimEngine used to hold ONE
        // policy instance shared by every per-layer unit, so a stateful
        // SlidingWindowPolicy's "last `window` plans" were really an
        // interleaving of EVERY layer's plans. A layer-local resident
        // still inside its own layer's window then got evicted because
        // OTHER layers' plans had pushed it out of the shared history.
        //
        // Engines now build one instance per layer
        // (`PolicyKind::build_per_layer`); this pins the behavior at the
        // policy level by replaying the engine's exact update order.
        let drive = |policies: &mut [&mut SlidingWindowPolicy]| -> (CacheUnit, CacheUnit) {
            let mut u0 = CacheUnit::meta_only(8);
            let mut u1 = CacheUnit::meta_only(8);
            // Token 0: layer 0 wants {1,2}, layer 1 wants {10,11}.
            // Token 1: layer 0 wants {2,3}, layer 1 repeats {10,11}.
            let tokens = [
                (plan_of(&[1, 2], &[], &[]), plan_of(&[10, 11], &[], &[])),
                (plan_of(&[2, 3], &[], &[]), plan_of(&[10, 11], &[], &[])),
            ];
            for (p0, p1) in &tokens {
                // The engine's order: layer 0 then layer 1, per token.
                let i1 = policies.len() - 1; // shared => same instance
                for na in policies[0].update(&mut u0, p0).load {
                    u0.insert(na.neuron, na.dtype, &[]);
                }
                for na in policies[i1].update(&mut u1, p1).load {
                    u1.insert(na.neuron, na.dtype, &[]);
                }
            }
            (u0, u1)
        };

        // Per-layer instances (the fix): neuron 1 was planned by layer 0
        // one token ago — inside the window of 2 — so it must survive
        // token 1's update no matter what layer 1's plans were.
        let (mut a, mut b) = (SlidingWindowPolicy::new(2), SlidingWindowPolicy::new(2));
        let (u0, u1) = drive(&mut [&mut a, &mut b]);
        assert!(
            u0.contains(1, Dtype::F16),
            "layer-local resident inside the window evicted by another layer's plans"
        );
        assert_eq!(u0.resident_neurons(), vec![1, 2, 3]);
        assert_eq!(u1.resident_neurons(), vec![10, 11]);

        // Shared instance (the old engine shape): layer 1's plans flush
        // layer 0's history out of the shared window, so neuron 1 is
        // gone — the §5.3 ablation corruption this PR fixes. Kept as a
        // demonstration that the test above is load-bearing.
        let mut shared = SlidingWindowPolicy::new(2);
        let (u0, _) = drive(&mut [&mut shared]);
        assert!(
            !u0.contains(1, Dtype::F16),
            "shared-instance aliasing no longer reproduces; update this test"
        );
    }

    #[test]
    fn partition_respects_capacity_and_covers_all_lanes() {
        Check::new(32, 0x9A27).run("partition by union", |rng| {
            let n = 40usize;
            let ratios = PrecisionRatios::new(0.1, 0.1, 0.2);
            let b = rng.range(1, 9);
            let plans: Vec<LayerPlan> = (0..b)
                .map(|_| {
                    plan_from_scores(
                        &(0..n).map(|_| rng.f32()).collect::<Vec<f32>>(),
                        &ratios,
                    )
                })
                .collect();
            let plan_sz = plans.iter().map(|p| p.total_active()).max().unwrap();
            let capacity = plan_sz + rng.range(0, n);
            let groups = partition_by_union(&plans, capacity);
            let mut seen: Vec<usize> = groups.iter().flatten().copied().collect();
            let in_order = seen.windows(2).all(|w| w[0] < w[1]);
            if !in_order {
                return Err("lanes reordered".into());
            }
            seen.sort_unstable();
            if seen != (0..b).collect::<Vec<usize>>() {
                return Err(format!("lanes lost: {seen:?} != 0..{b}"));
            }
            for g in &groups {
                let u = union_plans(g.iter().map(|&i| &plans[i]));
                if g.len() > 1 && u.total_active() > capacity {
                    return Err(format!(
                        "group union {} exceeds capacity {capacity}",
                        u.total_active()
                    ));
                }
            }
            Ok(())
        });
    }
}
