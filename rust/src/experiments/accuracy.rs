//! Executed accuracy experiments on the tiny trained model:
//!
//! - **Fig 10 proxy**: next-token accuracy + NLL across precision-ratio
//!   mixes under an *equal HBM byte budget* (the paper's HumanEval
//!   sweep). The claim reproduced: mixed precision beats any single
//!   precision at the same budget, and Algorithm 1's pick is at/near
//!   the optimum.
//! - **Table 14 proxy**: four task suites, dense-FP16 vs M2Cache
//!   (paper: HumanEval/PIQA/RTE/COPA with negligible degradation).
//!
//! The substitution rationale is in DESIGN.md §1: the paper's claims
//! here are *relative* (mixed ≥ single at equal memory; M2Cache ≈
//! dense), which the proxy preserves with real INT8/INT4 numerics.

use crate::coordinator::{tokenize, EngineConfig, ExecEngine};
use crate::experiments::ExpOpts;
use crate::precision::plan::PrecisionRatios;
use crate::util::bench::Table;
use anyhow::Result;
use std::path::Path;

/// Must match `_SENTENCES` in python/compile/model.py — the tiny
/// model's training domain. Eval suites draw from the same domain
/// (held-out orderings), so accuracy is meaningful.
pub const SENTENCES: [&str; 10] = [
    "the quick brown fox jumps over the lazy dog. ",
    "a journey of a thousand miles begins with a single step. ",
    "to be or not to be, that is the question. ",
    "all that glitters is not gold, said the old miner. ",
    "the cache keeps the hot neurons close to the compute. ",
    "large language models demand more memory than older gpus offer. ",
    "mixed precision trades bits for bandwidth without losing meaning. ",
    "the ssd holds the whole model while dram holds the next layers. ",
    "sustainable inference reuses yesterday's silicon for today's tokens. ",
    "every token activates only a fraction of the network's neurons. ",
];

/// Held-out eval windows: unseen sentence orderings from the domain.
pub fn eval_windows(n_windows: usize, window: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut text = String::new();
    while text.len() < n_windows * window + 64 {
        let mut order: Vec<usize> = (0..SENTENCES.len()).collect();
        rng.shuffle(&mut order);
        for i in order {
            text.push_str(SENTENCES[i]);
        }
    }
    let toks = tokenize(&text);
    (0..n_windows)
        .map(|i| toks[i * window..(i + 1) * window].to_vec())
        .collect()
}

/// Mean (nll, accuracy) over eval windows at the engine's current mix.
fn evaluate(eng: &mut ExecEngine, windows: &[Vec<u32>]) -> Result<(f64, f64)> {
    let mut nll = 0.0;
    let mut acc = 0.0;
    for w in windows {
        let (n, a) = eng.score_sequence(w)?;
        nll += n;
        acc += a;
    }
    let k = windows.len() as f64;
    Ok((nll / k, acc / k))
}

fn require_artifacts(opts: &ExpOpts) -> Result<()> {
    anyhow::ensure!(
        Path::new(opts.artifacts).join("layer_step.hlo.txt").exists(),
        "executed experiment needs artifacts — run `make artifacts`"
    );
    Ok(())
}

/// Fig 10: the precision-mix sweep. All mixes cost the same HBM bytes
/// (2·fp16 + 1·int8 + 0.5·int4 = 0.40 "value units" per neuron of
/// population — the budget of 20 % of neurons at FP16).
pub fn run_fig10(opts: ExpOpts) -> Result<String> {
    require_artifacts(&opts)?;
    let mixes: [(&str, PrecisionRatios); 6] = [
        ("fp16-only", PrecisionRatios::new(0.20, 0.0, 0.0)),
        ("int8-only", PrecisionRatios::new(0.0, 0.40, 0.0)),
        ("int4-only", PrecisionRatios::new(0.0, 0.0, 0.80)),
        ("mix-1:1:2*", PrecisionRatios::new(0.10, 0.10, 0.20)), // paper mix
        ("mix-lowfp16", PrecisionRatios::new(0.05, 0.20, 0.20)),
        ("mix-hifp16", PrecisionRatios::new(0.15, 0.05, 0.10)),
    ];
    let (n_win, win) = if opts.quick { (2, 32) } else { (4, 48) };
    let windows = eval_windows(n_win, win, 99);
    let mut eng = ExecEngine::new(Path::new(opts.artifacts), EngineConfig::full())?;

    // Dense reference for context.
    eng.set_ratios(PrecisionRatios::new(1.0, 0.0, 0.0));
    let (dense_nll, dense_acc) = evaluate(&mut eng, &windows)?;

    let mut t = Table::new(["mix", "budget(v)", "active%", "top1-acc", "nll"]);
    t.row([
        "dense-fp16(ref)".to_string(),
        "2.00".into(),
        "100%".into(),
        format!("{dense_acc:.3}"),
        format!("{dense_nll:.3}"),
    ]);
    let mut best = (String::new(), -1.0f64);
    for (name, r) in mixes {
        let budget = 2.0 * r.fp16 + r.int8 + 0.5 * r.int4;
        eng.set_ratios(r);
        let (nll, acc) = evaluate(&mut eng, &windows)?;
        if acc > best.1 {
            best = (name.to_string(), acc);
        }
        t.row([
            name.to_string(),
            format!("{budget:.2}"),
            format!("{:.0}%", r.active_fraction() * 100.0),
            format!("{acc:.3}"),
            format!("{nll:.3}"),
        ]);
    }
    Ok(format!(
        "Figure 10 — accuracy across precision mixes at equal HBM budget\n\
         (executed tiny model; * = the paper's 25/25/50 mix; paper claim:\n\
          mixed precision gains ~2.8% over single precision)\n{}\
         best mix: {} (acc {:.3}) vs best single-precision\n",
        t.render(),
        best.0,
        best.1
    ))
}

/// Table 14: dense vs M2Cache across four task suites.
pub fn run_table14(opts: ExpOpts) -> Result<String> {
    require_artifacts(&opts)?;
    let (n_win, win) = if opts.quick { (1, 32) } else { (3, 48) };
    // Four "tasks": different held-out shuffles + a repeated-pattern
    // suite + a single-domain suite (proxying task diversity).
    let suites: Vec<(&str, Vec<Vec<u32>>)> = vec![
        ("heldout-a", eval_windows(n_win, win, 7)),
        ("heldout-b", eval_windows(n_win, win, 13)),
        ("tech-domain", {
            let toks = tokenize(&SENTENCES[4..8].concat());
            vec![toks[..win.min(180)].to_vec(); n_win]
        }),
        ("proverbs", {
            let toks = tokenize(&SENTENCES[0..4].concat());
            vec![toks[..win.min(180)].to_vec(); n_win]
        }),
    ];
    let mut eng = ExecEngine::new(Path::new(opts.artifacts), EngineConfig::full())?;
    let mut t = Table::new(["suite", "dense-fp16 acc", "M2Cache acc", "delta"]);
    let mut worst: f64 = 0.0;
    for (name, windows) in &suites {
        eng.set_ratios(PrecisionRatios::new(1.0, 0.0, 0.0));
        let (_, dense) = evaluate(&mut eng, windows)?;
        eng.set_ratios(PrecisionRatios::new(0.10, 0.10, 0.20));
        let (_, m2) = evaluate(&mut eng, windows)?;
        worst = worst.max(dense - m2);
        t.row([
            name.to_string(),
            format!("{dense:.3}"),
            format!("{m2:.3}"),
            format!("{:+.3}", m2 - dense),
        ]);
    }
    Ok(format!(
        "Table 14 — task accuracy, dense vs M2Cache (paper: negligible loss)\n{}\
         worst-case degradation: {worst:.3}\n",
        t.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_windows_deterministic_and_sized() {
        let a = eval_windows(3, 40, 1);
        let b = eval_windows(3, 40, 1);
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        assert!(a.iter().all(|w| w.len() == 40));
        assert!(a[0] != eval_windows(3, 40, 2)[0], "seeds differ");
    }

    #[test]
    fn sentences_match_python_model() {
        // Cross-language contract: these strings seed both the training
        // corpus (python) and the eval windows (rust).
        assert_eq!(SENTENCES.len(), 10);
        assert!(SENTENCES[0].starts_with("the quick brown fox"));
        assert!(SENTENCES.iter().all(|s| s.ends_with(". ")));
        assert!(SENTENCES.iter().all(|s| s.is_ascii()));
    }
}
