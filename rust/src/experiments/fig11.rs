//! Figure 11: (a) time to first token, (b) decode GPU-time breakdown
//! per phase. TTFT rises with model size; the decode share of total
//! runtime falls (prefill amortizes).

use crate::coordinator::{EngineConfig, SimEngine};
use crate::experiments::ExpOpts;
use crate::memsim::HardwareSpec;
use crate::model::spec::ModelSpec;
use crate::util::bench::Table;

pub fn run(opts: ExpOpts) -> String {
    let gpu = crate::carbon::find_gpu("RTX3090").unwrap();
    let hw = HardwareSpec::rtx3090_testbed();
    let models = [
        ModelSpec::llama2_7b(),
        ModelSpec::llama2_13b(),
        ModelSpec::falcon_40b(),
        ModelSpec::llama2_70b(),
    ];
    let out_tokens = if opts.quick { 8 } else { 64 };
    let mut a = Table::new(["model", "TTFT s", "decode share of total"]);
    let mut b = Table::new([
        "model", "predict %", "attention %", "ffn %", "transfer-stall %",
        "cache-mgmt %", "other %",
    ]);
    for spec in &models {
        let mut e = SimEngine::new(spec.clone(), hw.clone(), EngineConfig::full());
        let r = e.run(64, out_tokens, gpu);
        a.row([
            spec.name.clone(),
            format!("{:.2}", r.ttft_s),
            format!("{:.0}%", (1.0 - r.ttft_s / r.total_s).max(0.0) * 100.0),
        ]);
        let p = &r.telemetry.phases;
        let tot = p.total_s().max(1e-12);
        let pct = |x: f64| format!("{:.1}%", 100.0 * x / tot);
        b.row([
            spec.name.clone(),
            pct(p.predict_s),
            pct(p.attention_s),
            pct(p.ffn_s),
            pct(p.transfer_s),
            pct(p.cache_mgmt_s),
            pct(p.other_s),
        ]);
    }
    format!(
        "Figure 11a — time to first token\n{}\nFigure 11b — decode time breakdown\n{}",
        a.render(),
        b.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttft_grows_with_model_size() {
        let out = run(ExpOpts {
            quick: true,
            artifacts: "artifacts",
        });
        let ttfts: Vec<f64> = out
            .lines()
            .filter(|l| l.starts_with("LLaMA") || l.starts_with("Falcon"))
            .take(4)
            .filter_map(|l| l.split_whitespace().nth(1)?.parse().ok())
            .collect();
        assert!(ttfts.len() >= 3);
        assert!(ttfts.last().unwrap() > ttfts.first().unwrap());
    }
}
