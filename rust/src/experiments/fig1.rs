//! Figure 1: operational carbon emission, FLOPs, and memory of GPUs
//! over release years — the paper's motivation chart. Reproduced from
//! the `carbon::gpu_db` specification table.

use crate::carbon::{GPUS, PAPER_INTENSITY_G_PER_KWH};
use crate::util::bench::Table;

pub fn run() -> String {
    let mut gpus: Vec<_> = GPUS.to_vec();
    gpus.sort_by_key(|g| g.year);
    let mut t = Table::new([
        "GPU", "year", "class", "TFLOPs", "HBM GiB", "BW GB/s", "TDP W",
        "OCE g/h", "embodied kg", "TFLOPs/W",
    ]);
    for g in &gpus {
        t.row([
            g.name.to_string(),
            g.year.to_string(),
            if g.top_tier { "top-tier" } else { "consumer" }.into(),
            format!("{:.1}", g.tflops),
            format!("{:.0}", g.mem_gib),
            format!("{:.0}", g.mem_bw_gbps),
            format!("{:.0}", g.tdp_w),
            format!("{:.0}", g.oce_per_hour_g(PAPER_INTENSITY_G_PER_KWH)),
            format!("{:.0}", g.embodied_kg),
            format!("{:.3}", g.tflops_per_watt()),
        ]);
    }
    let first = gpus.first().unwrap();
    let last = gpus.iter().max_by_key(|g| g.year).unwrap();
    let flops_growth = last.tflops / first.tflops;
    let mem_growth = last.mem_gib / first.mem_gib;
    format!(
        "Figure 1 — GPU carbon / FLOPs / memory by release year\n{}\n\
         {}->{}: FLOPs x{:.1}, memory x{:.1} — compute outpaces memory \
         x{:.1} (paper's motivating gap)\n\
         M40/H100 operational-carbon ratio: {:.2} (paper: ~1/3)\n",
        t.render(),
        first.name,
        last.name,
        flops_growth,
        mem_growth,
        flops_growth / mem_growth,
        crate::carbon::find_gpu("M40").unwrap().oce_per_hour_g(820.0)
            / crate::carbon::find_gpu("H100").unwrap().oce_per_hour_g(820.0),
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_all_gpus() {
        let out = super::run();
        for name in ["K40", "M40", "V100", "RTX3090", "A100", "H100"] {
            assert!(out.contains(name), "{name} missing");
        }
    }
}
