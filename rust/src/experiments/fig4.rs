//! Figure 4: end-to-end decode latency with FFN weights resident on
//! HBM vs DRAM vs SSD (the media study that motivates the multi-level
//! cache). Paper's measured shape: DRAM ≈ 10× HBM, SSD ≈ 85× HBM.

use crate::baseline::{media_decode_latency, Medium};
use crate::memsim::HardwareSpec;
use crate::model::spec::ModelSpec;
use crate::util::bench::Table;

pub fn run() -> String {
    let hw = HardwareSpec::rtx3090_testbed();
    let mut t = Table::new([
        "model", "HBM s/tok", "DRAM s/tok", "SSD s/tok", "DRAM/HBM", "SSD/HBM",
    ]);
    for spec in [ModelSpec::llama2_7b(), ModelSpec::llama2_13b()] {
        let hbm = media_decode_latency(&spec, &hw, Medium::Hbm);
        let dram = media_decode_latency(&spec, &hw, Medium::Dram);
        let ssd = media_decode_latency(&spec, &hw, Medium::Ssd);
        t.row([
            spec.name.clone(),
            format!("{hbm:.3}"),
            format!("{dram:.3}"),
            format!("{ssd:.3}"),
            format!("x{:.1}", dram / hbm),
            format!("x{:.1}", ssd / hbm),
        ]);
    }
    format!(
        "Figure 4 — decode latency by weight medium (paper: DRAM ~10x, SSD ~85x HBM)\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn shape_holds() {
        let out = super::run();
        assert!(out.contains("LLaMA-7B"));
        assert!(out.contains("DRAM/HBM"));
    }
}
