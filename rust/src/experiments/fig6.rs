//! Figure 6: overlapped-neuron ratio between adjacent tokens, per
//! layer. Two sources: the *executed* tiny model (real predictor-driven
//! active sets) when artifacts exist, and the calibrated synthetic 7B
//! trace otherwise/additionally.

use crate::coordinator::{EngineConfig, ExecEngine, SimEngine};
use crate::experiments::ExpOpts;
use crate::memsim::HardwareSpec;
use crate::model::spec::ModelSpec;
use crate::util::bench::Table;
use std::path::Path;

pub fn run(opts: ExpOpts) -> String {
    let mut out = String::from("Figure 6 — overlapped neuron ratio between tokens (paper: ~80%)\n");

    // Synthetic 7B trace through the simulated engine.
    let mut sim = SimEngine::new(
        ModelSpec::llama2_7b(),
        HardwareSpec::rtx3090_testbed(),
        EngineConfig::full(),
    );
    let gpu = crate::carbon::find_gpu("RTX3090").unwrap();
    let tokens = if opts.quick { 12 } else { 48 };
    let _ = sim.run(4, tokens, gpu);
    let per = sim.overlap.mean_per_layer();
    let mut t = Table::new(["layer", "overlap (sim 7B)"]);
    for (l, o) in per.iter().enumerate().take(16) {
        t.row([l.to_string(), format!("{o:.3}")]);
    }
    out.push_str(&t.render());
    out.push_str(&format!("sim-7B mean overlap: {:.3}\n\n", sim.overlap.mean()));

    // Executed tiny model (real predictor-driven plans).
    let art = Path::new(opts.artifacts);
    if art.join("layer_step.hlo.txt").exists() {
        match exec_overlap(art, if opts.quick { 24 } else { 64 }) {
            Ok((per, mean)) => {
                let mut t = Table::new(["layer", "overlap (executed tiny)"]);
                for (l, o) in per.iter().enumerate() {
                    t.row([l.to_string(), format!("{o:.3}")]);
                }
                out.push_str(&t.render());
                out.push_str(&format!("executed-tiny mean overlap: {mean:.3}\n"));
            }
            Err(e) => out.push_str(&format!("(executed path failed: {e:#})\n")),
        }
    } else {
        out.push_str("(run `make artifacts` for the executed-tiny series)\n");
    }
    out
}

fn exec_overlap(art: &Path, tokens: usize) -> anyhow::Result<(Vec<f64>, f64)> {
    let mut eng = ExecEngine::new(art, EngineConfig::full())?;
    let prompt = crate::coordinator::tokenize("the cache keeps the hot neurons close. ");
    let _ = eng.generate(&prompt, tokens)?;
    Ok((eng.overlap.mean_per_layer(), eng.overlap.mean()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_series_renders() {
        let out = run(ExpOpts {
            quick: true,
            artifacts: "/nonexistent",
        });
        assert!(out.contains("sim-7B mean overlap"));
    }
}
