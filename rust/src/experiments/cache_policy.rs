//! Cache-policy sweep: replay a recorded `(layer, token, plan)` trace
//! against every HBM cache organization — ATU / LRU / sliding-window
//! flat policies vs the set-associative + victim-buffer + way-predicted
//! design — at several capacities, and report hit ratio, DRAM→HBM
//! bytes, evictions, and management overhead per configuration.
//!
//! The replay is *offline*: it drives only `HbmPolicy::update` against
//! per-layer [`CacheUnit`]s (per-layer policy instances, the aliasing
//! fix this sweep exists to validate), so one captured trace compares
//! all organizations on identical access streams. The sweep's winner
//! (`setassoc w8 v32`) is the engine default,
//! [`crate::coordinator::config::DEFAULT_SETASSOC`].

use crate::cache::{CacheUnit, HbmPolicy as _};
use crate::coordinator::{EngineConfig, PolicyKind, SimEngine};
use crate::experiments::ExpOpts;
use crate::memsim::HardwareSpec;
use crate::model::spec::ModelSpec;
use crate::precision::quant::wire_bytes;
use crate::sparsity::PlanTrace;
use crate::util::bench::Table;

/// One configuration's replay totals over a whole trace.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub policy: String,
    /// Unit slot count every layer was given.
    pub capacity: usize,
    pub hits: u64,
    /// Plan entries fetched from DRAM (== misses: every plan entry is
    /// either resident or loaded).
    pub loads: u64,
    pub dram_to_hbm: u64,
    pub evictions: u64,
    pub victim_hits: u64,
    pub way_hits: u64,
    pub way_lookups: u64,
    /// Wall time spent inside `HbmPolicy::update` (management overhead).
    pub mgmt_s: f64,
}

impl SweepRow {
    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.loads;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn way_accuracy(&self) -> f64 {
        if self.way_lookups == 0 {
            0.0
        } else {
            self.way_hits as f64 / self.way_lookups as f64
        }
    }
}

fn label(kind: PolicyKind) -> String {
    match kind {
        PolicyKind::Atu => "atu".into(),
        PolicyKind::Lru => "lru".into(),
        PolicyKind::SlidingWindow(w) => format!("window{w}"),
        PolicyKind::SetAssoc { ways, victim } => format!("setassoc w{ways} v{victim}"),
    }
}

/// Replay `trace` against `kind` with per-layer units of `capacity`
/// slots. `values`/`int4_group` size the wire-format byte accounting
/// (use the captured model's `d_model` and the engine's group size).
pub fn replay(
    trace: &PlanTrace,
    kind: PolicyKind,
    capacity: usize,
    values: usize,
    int4_group: usize,
) -> SweepRow {
    let mut units: Vec<CacheUnit> = (0..trace.n_layers)
        .map(|_| CacheUnit::meta_only(capacity))
        .collect();
    // Per-layer instances — replaying a shared instance would reproduce
    // the aliasing bug this harness was built to catch.
    let mut policies = kind.build_per_layer(trace.n_layers);
    let mut row = SweepRow {
        policy: label(kind),
        capacity,
        hits: 0,
        loads: 0,
        dram_to_hbm: 0,
        evictions: 0,
        victim_hits: 0,
        way_hits: 0,
        way_lookups: 0,
        mgmt_s: 0.0,
    };
    for r in &trace.records {
        let l = r.layer as usize;
        let t0 = std::time::Instant::now();
        let upd = policies[l].update(&mut units[l], &r.plan);
        row.mgmt_s += t0.elapsed().as_secs_f64();
        for na in &upd.load {
            units[l].insert(na.neuron, na.dtype, &[]);
            row.dram_to_hbm += wire_bytes(na.dtype, values, int4_group);
        }
        row.hits += upd.hits as u64;
        row.loads += upd.load.len() as u64;
        row.evictions += upd.evicted as u64;
        row.victim_hits += upd.victim_hits as u64;
        row.way_hits += upd.way_hits as u64;
        row.way_lookups += upd.way_lookups as u64;
    }
    row
}

/// Capture a plan trace from the simulated tiny model: `tokens` decode
/// steps after an 8-token prefill, recorded in engine update order.
pub fn capture_tiny_trace(tokens: usize) -> PlanTrace {
    let mut sim = SimEngine::new(
        ModelSpec::tiny(),
        HardwareSpec::rtx3090_testbed(),
        EngineConfig::full(),
    );
    sim.capture_plans();
    let gpu = crate::carbon::find_gpu("RTX3090").expect("RTX3090 in gpu table");
    let _ = sim.run(8, tokens, gpu);
    sim.take_captured_plans().expect("capture was enabled")
}

/// The organizations the sweep compares: the three flat baselines plus
/// a ways × victim-buffer grid around the landed default.
pub fn sweep_kinds() -> Vec<PolicyKind> {
    vec![
        PolicyKind::Atu,
        PolicyKind::Lru,
        PolicyKind::SlidingWindow(3),
        PolicyKind::SetAssoc { ways: 4, victim: 0 },
        PolicyKind::SetAssoc { ways: 4, victim: 16 },
        PolicyKind::SetAssoc { ways: 8, victim: 32 },
        PolicyKind::SetAssoc { ways: 16, victim: 64 },
    ]
}

/// Full sweep: every organization × capacity factor {1.0, 1.5, 2.0}
/// of the trace's largest plan (equal capacity across policies at each
/// point — `capacity_factor` slack is deliberately NOT applied, so the
/// comparison isolates the organization, not the budget).
pub fn sweep(trace: &PlanTrace, values: usize, int4_group: usize) -> Vec<SweepRow> {
    let base = trace.max_plan_entries().max(1);
    let mut rows = Vec::new();
    for factor in [2, 3, 4] {
        let cap = base * factor / 2; // 1.0x, 1.5x, 2.0x
        for kind in sweep_kinds() {
            rows.push(replay(trace, kind, cap, values, int4_group));
        }
    }
    rows
}

pub fn run(opts: ExpOpts) -> String {
    let tokens = if opts.quick { 16 } else { 64 };
    let trace = capture_tiny_trace(tokens);
    let spec = ModelSpec::tiny();
    let group = EngineConfig::full().int4_group;
    let rows = sweep(&trace, spec.d_model, group);

    let mut out = format!(
        "Cache-policy sweep — {} records over {} layers (tiny sim, {} decode tokens), \
         max plan {} entries\n",
        trace.len(),
        trace.n_layers,
        tokens,
        trace.max_plan_entries()
    );
    let mut t = Table::new([
        "policy", "cap", "hit%", "loads", "dram→hbm KB", "evict", "victim", "way-acc",
        "mgmt µs",
    ]);
    for r in &rows {
        t.row([
            r.policy.clone(),
            r.capacity.to_string(),
            format!("{:.1}", 100.0 * r.hit_ratio()),
            r.loads.to_string(),
            format!("{:.1}", r.dram_to_hbm as f64 / 1024.0),
            r.evictions.to_string(),
            r.victim_hits.to_string(),
            format!("{:.2}", r.way_accuracy()),
            format!("{:.0}", r.mgmt_s * 1e6),
        ]);
    }
    out.push_str(&t.render());

    // The landed default vs the ATU baseline at the same capacity.
    let atu = rows.iter().find(|r| r.policy == "atu").unwrap();
    let landed = rows
        .iter()
        .find(|r| r.policy == "setassoc w8 v32" && r.capacity == atu.capacity)
        .unwrap();
    out.push_str(&format!(
        "landed default (setassoc w8 v32 @ cap {}): hit {:.1}% vs atu {:.1}%, \
         dram→hbm {} vs {} bytes\n",
        landed.capacity,
        100.0 * landed.hit_ratio(),
        100.0 * atu.hit_ratio(),
        landed.dram_to_hbm,
        atu.dram_to_hbm
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::plan::LayerPlan;

    fn toy_trace() -> PlanTrace {
        let mut t = PlanTrace::new(2);
        // Layer 0 alternates between two plans; layer 1 is steady —
        // slack-capacity policies should keep both of layer 0's sets.
        let a = LayerPlan {
            fp16: vec![1, 2, 3],
            int8: vec![],
            int4: vec![],
        };
        let b = LayerPlan {
            fp16: vec![4, 5, 6],
            int8: vec![],
            int4: vec![],
        };
        let c = LayerPlan {
            fp16: vec![9, 10, 11],
            int8: vec![],
            int4: vec![],
        };
        for _ in 0..4 {
            t.record(0, &a);
            t.record(1, &c);
            t.record(0, &b);
            t.record(1, &c);
        }
        t
    }

    #[test]
    fn setassoc_dominates_atu_on_replay() {
        let t = toy_trace();
        let cap = t.max_plan_entries() * 2;
        let atu = replay(&t, PolicyKind::Atu, cap, 64, 32);
        let sa = replay(
            &t,
            PolicyKind::SetAssoc { ways: 8, victim: 32 },
            cap,
            64,
            32,
        );
        assert_eq!(atu.hits + atu.loads, sa.hits + sa.loads, "same lookups");
        assert!(sa.hits >= atu.hits, "sa {} < atu {}", sa.hits, atu.hits);
        assert!(sa.dram_to_hbm <= atu.dram_to_hbm);
        // On this alternating trace the slack actually pays off.
        assert!(sa.hits > atu.hits, "alternating plans must beat ATU");
    }

    #[test]
    fn replay_is_deterministic() {
        let t = toy_trace();
        let kind = PolicyKind::SetAssoc { ways: 4, victim: 8 };
        let a = replay(&t, kind, 8, 64, 32);
        let b = replay(&t, kind, 8, 64, 32);
        assert_eq!(
            (a.hits, a.loads, a.dram_to_hbm, a.evictions, a.victim_hits),
            (b.hits, b.loads, b.dram_to_hbm, b.evictions, b.victim_hits)
        );
    }

    #[test]
    fn quick_sweep_renders_and_ranks() {
        let out = run(ExpOpts {
            quick: true,
            artifacts: "/nonexistent",
        });
        assert!(out.contains("landed default"), "{out}");
        assert!(out.contains("atu"), "{out}");
        assert!(out.contains("setassoc w8 v32"), "{out}");
    }
}
