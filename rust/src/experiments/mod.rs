//! Experiment drivers — one per figure/table in the paper's evaluation.
//! Each returns its rendered table(s) so the CLI, the bench harness,
//! and EXPERIMENTS.md all share one source of truth.
//!
//! | id      | paper artifact                                   |
//! |---------|--------------------------------------------------|
//! | fig1    | GPU carbon/FLOPs/memory by release year          |
//! | fig4    | decode latency with weights on HBM/DRAM/SSD      |
//! | fig5    | transfer time + bandwidth vs tensor size         |
//! | fig6    | overlapped-neuron ratio between adjacent tokens  |
//! | fig9    | generation speed vs ZeRO-Inference               |
//! | fig10   | accuracy across precision-ratio mixes (executed) |
//! | fig11   | time-to-first-token + GPU time breakdown         |
//! | fig12   | carbon footprint vs ZeRO-Inference               |
//! | fig13   | ablation: +MP / +Cache / +SSD                    |
//! | table14 | task accuracy, dense vs M2Cache (executed)       |
//! | alg1    | uncertainty-guided ratio search                  |
//! | cache_policy | HBM cache-organization sweep over a plan trace |

pub mod accuracy;
pub mod cache_policy;
pub mod fig1;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig9;
pub mod ratio;

use anyhow::{bail, Result};

/// Scale knob: benches use `quick=true` (fewer tokens) so the full
/// suite stays minutes, not hours; the CLI default is the full size.
#[derive(Debug, Clone, Copy)]
pub struct ExpOpts {
    pub quick: bool,
    /// Artifacts directory for executed experiments.
    pub artifacts: &'static str,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            quick: false,
            artifacts: "artifacts",
        }
    }
}

/// Run an experiment by id; returns the rendered report.
pub fn run(id: &str, opts: ExpOpts) -> Result<String> {
    Ok(match id {
        "fig1" => fig1::run(),
        "fig4" => fig4::run(),
        "fig5" => fig5::run(),
        "fig6" => fig6::run(opts),
        "fig9" => fig9::run(opts),
        "fig10" => accuracy::run_fig10(opts)?,
        "fig11" => fig11::run(opts),
        "fig12" => fig12::run(opts),
        "fig13" => fig13::run(opts),
        "table14" => accuracy::run_table14(opts)?,
        "alg1" => ratio::run(opts)?,
        "cache_policy" => cache_policy::run(opts),
        other => bail!(
            "unknown experiment {other:?}; available: fig1 fig4 fig5 fig6 \
             fig9 fig10 fig11 fig12 fig13 table14 alg1 cache_policy"
        ),
    })
}

pub const ALL: [&str; 12] = [
    "fig1", "fig4", "fig5", "fig6", "fig9", "fig10", "fig11", "fig12",
    "fig13", "table14", "alg1", "cache_policy",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_errors() {
        assert!(run("fig99", ExpOpts::default()).is_err());
    }

    #[test]
    fn fig1_always_available() {
        let out = run("fig1", ExpOpts::default()).unwrap();
        assert!(out.contains("H100"));
    }
}
