//! Figure 13: component ablation on LLaMA-13B — decode speed, carbon,
//! and GPU/DRAM usage as M2Cache's pieces stack up:
//!   baseline (dense offload) → +MP Inference → +LRU(ATU) Cache → +SSDs
//! Paper: ~1 tok/s after MP, 4.62 tok/s with the cache, and +SSDs
//! saves ~22 GB DRAM at unchanged speed/carbon.

use crate::baseline::ZeroInfinityEngine;
use crate::coordinator::{EngineConfig, SimEngine};
use crate::experiments::ExpOpts;
use crate::memsim::HardwareSpec;
use crate::model::spec::ModelSpec;
use crate::util::bench::Table;

pub fn run(opts: ExpOpts) -> String {
    let gpu = crate::carbon::find_gpu("RTX3090").unwrap();
    let hw = HardwareSpec::rtx3090_testbed();
    let spec = ModelSpec::llama2_13b();
    let (inp, outp) = if opts.quick { (8, 12) } else { (64, 64) };

    let mut t = Table::new([
        "config", "tok/s", "gCO2", "GPU GiB", "DRAM GiB", "pcie GiB", "hit%",
    ]);

    // Stage 0: dense streaming baseline.
    let mut zi = ZeroInfinityEngine::new(spec.clone(), hw.clone(), 64 << 30);
    let rz = zi.run(inp, outp, gpu);
    t.row([
        "ZeRO-Inf(dense)".to_string(),
        format!("{:.2}", rz.tokens_per_s),
        format!("{:.1}", rz.carbon.total_g()),
        "-".into(),
        format!("{:.1}", rz.telemetry.peak_dram_bytes as f64 / (1u64 << 30) as f64),
        format!("{:.1}", rz.telemetry.traffic.dram_to_hbm as f64 / (1u64 << 30) as f64),
        "-".into(),
    ]);

    let stages: [(&str, EngineConfig); 3] = [
        ("+MP-Inference", EngineConfig::ablation_mp_only()),
        ("+ATU-Cache", EngineConfig::ablation_with_cache()),
        ("+SSDs", {
            let mut c = EngineConfig::full();
            c.dram_capacity = 12 << 30;
            c
        }),
    ];
    for (name, cfg) in stages {
        let mut e = SimEngine::new(spec.clone(), hw.clone(), cfg);
        let r = e.run(inp, outp, gpu);
        t.row([
            name.to_string(),
            format!("{:.2}", r.tokens_per_s),
            format!("{:.1}", r.carbon.total_g()),
            format!("{:.1}", r.telemetry.peak_hbm_bytes as f64 / (1u64 << 30) as f64),
            format!("{:.1}", r.telemetry.peak_dram_bytes as f64 / (1u64 << 30) as f64),
            format!("{:.1}", r.telemetry.traffic.dram_to_hbm as f64 / (1u64 << 30) as f64),
            format!("{:.0}%", r.telemetry.hit_ratio() * 100.0),
        ]);
    }
    format!(
        "Figure 13 — ablation on LLaMA-13B (paper: ~1 -> 4.62 tok/s; +SSDs saves ~22 GB DRAM)\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stages_improve_monotonically() {
        let out = run(ExpOpts {
            quick: true,
            artifacts: "artifacts",
        });
        let toks: Vec<f64> = out
            .lines()
            .filter(|l| {
                l.starts_with("ZeRO-Inf(dense)") || l.starts_with("+MP") || l.starts_with("+ATU") || l.starts_with("+SSDs")
            })
            .filter_map(|l| {
                l.split_whitespace()
                    .find(|c| c.parse::<f64>().is_ok())
                    .and_then(|c| c.parse().ok())
            })
            .collect();
        assert_eq!(toks.len(), 4, "{out}");
        assert!(toks[1] > toks[0], "+MP beats dense: {toks:?}");
        assert!(toks[2] > toks[1], "+cache beats +MP: {toks:?}");
        // +SSDs must not slow things down materially (paper: unchanged).
        assert!(toks[3] > 0.8 * toks[2], "+SSD keeps speed: {toks:?}");
    }

    #[test]
    fn ssd_stage_saves_dram() {
        let out = run(ExpOpts {
            quick: true,
            artifacts: "artifacts",
        });
        let dram: Vec<f64> = out
            .lines()
            .filter(|l| l.starts_with("+ATU") || l.starts_with("+SSDs"))
            .filter_map(|l| l.split_whitespace().nth(4)?.parse().ok())
            .collect();
        assert_eq!(dram.len(), 2, "{out}");
        assert!(dram[1] < dram[0], "DRAM shrinks with SSD tier: {dram:?}");
    }
}
