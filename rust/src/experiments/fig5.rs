//! Figure 5: transfer time (left) and achieved bandwidth (right) as a
//! function of tensor size, HBM-internal vs DRAM-internal copies. The
//! paper's observations: neuron-sized HBM copies are ~10× slower than
//! DRAM (launch overhead), while the ordering flips for large copies —
//! which is why the HBM cache is laid out as contiguous units updated
//! by ATU rather than per-neuron shuffling.

use crate::memsim::{HardwareSpec, Link};
use crate::util::bench::Table;

pub fn run() -> String {
    let hw = HardwareSpec::rtx3090_testbed();
    let sizes: [u64; 9] = [
        4 << 10,
        16 << 10,
        64 << 10,
        256 << 10,
        1 << 20,
        4 << 20,
        16 << 20,
        64 << 20,
        256 << 20,
    ];
    let mut t = Table::new([
        "size", "HBM µs", "DRAM µs", "HBM GB/s", "DRAM GB/s", "HBM/DRAM time",
    ]);
    for &s in &sizes {
        let h = hw.links.get(Link::HbmInternal);
        let d = hw.links.get(Link::DramInternal);
        let th = h.time_s(s);
        let td = d.time_s(s);
        t.row([
            crate::util::text::fmt_bytes(s),
            format!("{:.1}", th * 1e6),
            format!("{:.1}", td * 1e6),
            format!("{:.1}", h.effective_bw(s) / 1e9),
            format!("{:.1}", d.effective_bw(s) / 1e9),
            format!("x{:.1}", th / td),
        ]);
    }
    format!(
        "Figure 5 — transfer time / bandwidth vs tensor size\n\
         (neuron record ≈ 16-32 KiB: HBM ~10x slower; crossover at ~MiB sizes)\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn crossover_visible() {
        let out = super::run();
        assert!(out.contains("4.00 KiB") || out.contains("4 KiB") || out.contains("4096 B"),
                "small size row present:\n{out}");
        assert!(out.contains("256.00 MiB"));
    }
}
