//! Figure 12: per-request carbon footprint, M2Cache vs ZeRO-Inference
//! (paper: reductions of 42–280 gCO2 per request, up to ×7.67).

use crate::baseline::ZeroInfinityEngine;
use crate::coordinator::{EngineConfig, SimEngine};
use crate::experiments::ExpOpts;
use crate::memsim::HardwareSpec;
use crate::model::spec::ModelSpec;
use crate::util::bench::Table;

pub fn run(opts: ExpOpts) -> String {
    let gpu = crate::carbon::find_gpu("RTX3090").unwrap();
    let hw = HardwareSpec::rtx3090_testbed();
    let models = [
        ModelSpec::llama2_7b(),
        ModelSpec::llama2_13b(),
        ModelSpec::falcon_40b(),
        ModelSpec::llama2_70b(),
    ];
    let (inp, outp) = if opts.quick { (16, 16) } else { (64, 128) };
    let mut t = Table::new([
        "model", "M2Cache gCO2", "ZeRO-Inf gCO2", "saved g", "reduction",
        "M2 g/token", "ZI g/token",
    ]);
    for spec in &models {
        let mut m2 = SimEngine::new(spec.clone(), hw.clone(), EngineConfig::full());
        let rm = m2.run(inp, outp, gpu);
        let mut zi = ZeroInfinityEngine::new(spec.clone(), hw.clone(), 64 << 30);
        let rz = zi.run(inp, outp, gpu);
        let (m, z) = (rm.carbon.total_g(), rz.carbon.total_g());
        t.row([
            spec.name.clone(),
            format!("{m:.1}"),
            format!("{z:.1}"),
            format!("{:.1}", z - m),
            format!("x{:.2}", z / m),
            format!("{:.3}", m / outp as f64),
            format!("{:.3}", z / outp as f64),
        ]);
    }
    format!(
        "Figure 12 — carbon footprint per request (paper: 42–280 g saved, up to x7.67)\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn m2cache_always_lower_carbon() {
        let out = run(ExpOpts {
            quick: true,
            artifacts: "artifacts",
        });
        for line in out.lines().skip(3) {
            let cells: Vec<&str> = line.split_whitespace().collect();
            if cells.len() >= 5 && (line.starts_with("LLaMA") || line.starts_with("Falcon")) {
                // Quick runs round small absolute grams to 0.0; the
                // reduction factor is the robust invariant.
                let reduction: f64 = cells[4].trim_start_matches('x').parse().unwrap();
                assert!(reduction > 1.0, "{line}");
            }
        }
    }
}
