//! Algorithm 1: the uncertainty-guided offline neuron-ratio search,
//! run two ways:
//!  - *executed*: UQEst (Eq. 2) measured on the tiny model's own
//!    decoding entropy through the real engine;
//!  - *surrogate*: the calibrated analytic UQEst at 13B geometry
//!    (always available).

use crate::coordinator::{tokenize, EngineConfig, ExecEngine};
use crate::experiments::ExpOpts;
use crate::precision::plan::PrecisionRatios;
use crate::precision::search::{ratio_search, SurrogateUq, UncertaintyEval};
use crate::util::bench::Table;
use anyhow::Result;
use std::path::Path;

/// UQEst evaluator over the executed engine.
pub struct UqEngineEval<'a> {
    pub engine: &'a mut ExecEngine,
    pub prompts: Vec<Vec<u32>>,
    pub gen_tokens: usize,
}

impl UncertaintyEval for UqEngineEval<'_> {
    fn uqest(&mut self, ratios: &PrecisionRatios) -> f64 {
        self.engine.set_ratios(*ratios);
        let mut total = 0.0;
        for p in &self.prompts {
            total += self
                .engine
                .uqest(p, self.gen_tokens)
                .unwrap_or(f64::INFINITY);
        }
        total
    }
}

pub fn run(opts: ExpOpts) -> Result<String> {
    let mut out = String::from("Algorithm 1 — uncertainty-guided ratio search\n\n");

    // Surrogate at 13B geometry.
    let mut surrogate = SurrogateUq::default();
    let res = ratio_search(&mut surrogate, 0.8, 0.05, 4.0);
    let mut t = Table::new(["r_fp16", "r_int8", "r_int4", "UQEst"]);
    for step in &res.trajectory {
        t.row([
            format!("{:.3}", step.ratios.fp16),
            format!("{:.3}", step.ratios.int8),
            format!("{:.3}", step.ratios.int4),
            format!("{:.3}", step.uq),
        ]);
    }
    out.push_str("surrogate (13B geometry):\n");
    out.push_str(&t.render());
    out.push_str(&format!(
        "best: fp16={:.3} int8={:.3} int4={:.3} (UQ {:.3})\n\n",
        res.best.fp16, res.best.int8, res.best.int4, res.best_uq
    ));

    // Executed on the tiny model.
    if Path::new(opts.artifacts).join("layer_step.hlo.txt").exists() {
        let mut eng = ExecEngine::new(Path::new(opts.artifacts), EngineConfig::full())?;
        let prompts = vec![
            tokenize("the quick brown fox "),
            tokenize("mixed precision trades "),
        ];
        let gen = if opts.quick { 8 } else { 16 };
        let mut eval = UqEngineEval {
            engine: &mut eng,
            prompts,
            gen_tokens: gen,
        };
        let step = if opts.quick { 0.2 } else { 0.1 };
        let res = ratio_search(&mut eval, 0.8, step, 4.0);
        let mut t = Table::new(["r_fp16", "r_int8", "r_int4", "UQEst(executed)"]);
        for s in &res.trajectory {
            t.row([
                format!("{:.3}", s.ratios.fp16),
                format!("{:.3}", s.ratios.int8),
                format!("{:.3}", s.ratios.int4),
                format!("{:.3}", s.uq),
            ]);
        }
        out.push_str("executed (tiny model, Eq. 2 entropy):\n");
        out.push_str(&t.render());
        out.push_str(&format!(
            "best: fp16={:.3} int8={:.3} int4={:.3} (UQ {:.3})\n",
            res.best.fp16, res.best.int8, res.best.int4, res.best_uq
        ));
    } else {
        out.push_str("(run `make artifacts` for the executed search)\n");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn surrogate_path_always_renders() {
        let out = run(ExpOpts {
            quick: true,
            artifacts: "/nonexistent",
        })
        .unwrap();
        assert!(out.contains("surrogate"));
        assert!(out.contains("best:"));
    }
}
