//! Figure 9: end-to-end generation speed (tokens/s), M2Cache vs
//! ZeRO-Inference, across LLaMA-7B/13B/70B + Falcon-40B, input lengths
//! {64, 128} and output lengths {64, 128, 512}. Paper headline: up to
//! ~10× on 7B, ~14× on 13B; 70B runs at ~0.38 tok/s where ZeRO-Inf
//! collapses to ~0.02.

use crate::baseline::ZeroInfinityEngine;
use crate::coordinator::{EngineConfig, SimEngine};
use crate::experiments::ExpOpts;
use crate::memsim::HardwareSpec;
use crate::model::spec::ModelSpec;
use crate::util::bench::Table;

pub fn run(opts: ExpOpts) -> String {
    let gpu = crate::carbon::find_gpu("RTX3090").unwrap();
    let hw = HardwareSpec::rtx3090_testbed();
    let dram = 64u64 << 30;
    let models = [
        ModelSpec::llama2_7b(),
        ModelSpec::llama2_13b(),
        ModelSpec::falcon_40b(),
        ModelSpec::llama2_70b(),
    ];
    let inputs = if opts.quick { vec![64] } else { vec![64, 128] };
    let outputs = if opts.quick {
        vec![32]
    } else {
        vec![64, 128, 512]
    };
    let mut t = Table::new([
        "model", "in", "out", "M2Cache tok/s", "ZeRO-Inf tok/s", "speedup",
    ]);
    for spec in &models {
        for &inp in &inputs {
            for &outp in &outputs {
                let mut cfg = EngineConfig::full();
                cfg.dram_capacity = dram - (8 << 30); // OS + runtime keep 8 GiB
                let mut m2 = SimEngine::new(spec.clone(), hw.clone(), cfg);
                let rm = m2.run(inp, outp, gpu);
                let mut zi = ZeroInfinityEngine::new(spec.clone(), hw.clone(), dram);
                let rz = zi.run(inp, outp, gpu);
                t.row([
                    spec.name.clone(),
                    inp.to_string(),
                    outp.to_string(),
                    format!("{:.3}", rm.tokens_per_s),
                    format!("{:.3}", rz.tokens_per_s),
                    format!("x{:.1}", rm.tokens_per_s / rz.tokens_per_s),
                ]);
            }
        }
    }
    format!(
        "Figure 9 — generation speed, M2Cache vs ZeRO-Inference\n\
         (paper: up to x10.51 speedup; 70B ~0.38 tok/s vs ~0.02)\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn m2cache_wins_everywhere() {
        let out = run(ExpOpts {
            quick: true,
            artifacts: "artifacts",
        });
        // every speedup cell is x<number> >= 1
        for line in out.lines().skip(4) {
            if let Some(idx) = line.rfind('x') {
                if let Ok(v) = line[idx + 1..].trim().parse::<f64>() {
                    assert!(v > 1.0, "speedup {v} in {line}");
                }
            }
        }
    }
}
