//! m2cache CLI — leader entrypoint for the M2Cache reproduction.
//!
//! Subcommands:
//!   info                         platform + artifact + model summary
//!   generate  [--prompt ...]     executed tiny-model generation
//!   serve     [--addr ...]       TCP serving over the executed engine
//!   simulate  [--model 13B ...]  simulated run on a large geometry
//!   fleet     [--gpus A100,M40]  heterogeneous replica fleet (virtual)
//!   experiment <id>|all          regenerate a paper figure/table
//!   ratio-search                 Algorithm 1 (alias: experiment alg1)
//!   carbon-report                Fig 1 + Fig 12 summary
//!
//! Common flags: --artifacts DIR (default: artifacts), --quick

use m2cache::coordinator::{
    detokenize, tokenize, EngineConfig, ExecEngine, PolicyKind, Request, ServingCore,
    SessionEvent, SimEngine,
};
use m2cache::experiments::{self, ExpOpts};
use m2cache::memsim::HardwareSpec;
use m2cache::model::spec::ModelSpec;
use m2cache::util::cli::Args;
use std::path::Path;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match dispatch(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn opts_of(args: &Args) -> ExpOpts {
    let artifacts: &'static str =
        Box::leak(args.get_or("artifacts", "artifacts").to_string().into_boxed_str());
    ExpOpts {
        quick: args.flag("quick"),
        artifacts,
    }
}

fn engine_config(args: &Args) -> EngineConfig {
    let mut cfg = EngineConfig::full();
    if let Some(p) = args.get("policy") {
        cfg.policy = PolicyKind::parse(p).unwrap_or(PolicyKind::Atu);
    }
    if let Some(d) = args.get("dram-gib") {
        cfg.dram_capacity = (d.parse::<f64>().unwrap_or(40.0) * (1u64 << 30) as f64) as u64;
    }
    cfg.fixed_layers = args.get_usize("fixed-layers", cfg.fixed_layers);
    cfg.preload_depth = args.get_usize("preload-depth", cfg.preload_depth);
    // Pipelined datapath: --io-threads widens the SSD preloader's pool
    // (and the staging workers); --pipeline turns on speculative
    // next-layer staging + overlapped KV restore. Both default off so
    // the synchronous datapath stays bit-identical.
    cfg.io_threads = args.get_usize("io-threads", cfg.io_threads).max(1);
    cfg.pipeline = args.flag("pipeline");
    cfg.max_sessions = args.get_usize("sessions", cfg.max_sessions).max(1);
    // Tiered KV: physical HBM slots (default = sessions). Fewer slots
    // than sessions oversubscribes serving — the scheduler preempts by
    // spilling KV to the DRAM spill area / SSD spill file.
    cfg.kv_slots = args
        .get("kv-slots")
        .and_then(|v| v.parse::<usize>().ok())
        .map(|n| n.max(1));
    if let Some(mib) = args.get("kv-spill-dram-mib").and_then(|v| v.parse::<u64>().ok()) {
        cfg.kv_spill_dram = mib << 20;
    }
    cfg.preempt_cap = args.get_usize("preempt-cap", cfg.preempt_cap as usize) as u32;
    cfg.prefill_chunk = args.get_usize("prefill-chunk", cfg.prefill_chunk).max(1);
    cfg.starvation_guard =
        args.get_usize("starvation-guard", cfg.starvation_guard as usize) as u64;
    // Batched forward: one shared per-layer pass for all co-resident
    // sessions (--batch-kernel additionally dispatches lane groups
    // through the stacked HLO when the artifacts provide one).
    cfg.batch_kernel = args.flag("batch-kernel");
    cfg.batch = args.flag("batch") || cfg.batch_kernel;
    // Continuous admission is the v2 default; --no-continuous restores
    // assembly-only admission (arrivals wait out in-flight turns).
    if args.flag("no-continuous") {
        cfg.continuous = false;
    }
    // Chaos engineering: seeded fault injection into the KV spill
    // path. All probabilities default to 0.0 (off); the faulty backend
    // is only installed when one is non-zero, so plain runs stay
    // bit-identical to the pre-fault-injection engine.
    if let Some(p) = args.get("fault-read").and_then(|v| v.parse().ok()) {
        cfg.faults.read_error = p;
    }
    if let Some(p) = args.get("fault-write").and_then(|v| v.parse().ok()) {
        cfg.faults.write_error = p;
    }
    if let Some(p) = args.get("fault-torn").and_then(|v| v.parse().ok()) {
        cfg.faults.torn_write = p;
    }
    if let Some(p) = args.get("fault-flip").and_then(|v| v.parse().ok()) {
        cfg.faults.bit_flip = p;
    }
    if let Some(p) = args.get("fault-spike").and_then(|v| v.parse().ok()) {
        cfg.faults.latency_spike = p;
    }
    if let Some(ms) = args.get("fault-spike-ms").and_then(|v| v.parse().ok()) {
        cfg.faults.spike_ms = ms;
    }
    if let Some(s) = args.get("fault-seed").and_then(|v| v.parse().ok()) {
        cfg.faults.seed = s;
    }
    cfg.spill_retries = args
        .get_usize("spill-retries", cfg.spill_retries as usize)
        .max(1) as u32;
    if args.flag("no-ssd") {
        cfg.use_ssd = false;
    }
    if args.flag("no-cache") {
        cfg.use_hbm_cache = false;
    }
    if args.flag("no-mp") {
        cfg.use_mp = false;
    }
    cfg
}

fn dispatch(cmd: &str, args: &Args) -> anyhow::Result<()> {
    match cmd {
        "info" => info(args),
        "generate" => generate(args),
        "serve" => serve(args),
        "simulate" => simulate(args),
        "fleet" => fleet(args),
        "experiment" => experiment(args),
        "ratio-search" => {
            print!("{}", experiments::run("alg1", opts_of(args))?);
            Ok(())
        }
        "carbon-report" => {
            print!("{}", experiments::run("fig1", opts_of(args))?);
            println!();
            print!("{}", experiments::run("fig12", opts_of(args))?);
            Ok(())
        }
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
m2cache — mixed-precision multi-level-cached LLM inference (paper repro)

USAGE: m2cache <command> [flags]

COMMANDS:
  info            platform, artifacts, model geometries
  generate        run the executed tiny model: --prompt TEXT --tokens N
                  [--stream]           print tokens as they decode (the
                                       event-driven serving core)
                  [--capture-trace F]  record the (layer, token, plan)
                                       stream to F for the offline
                                       cache-policy sweep
  serve           TCP server: --addr HOST:PORT [--max-requests N]
                  [--sessions N]       interleave up to N decode sessions
                  [--kv-slots K]       physical HBM KV slots (default N;
                                       K < N oversubscribes — preempted
                                       sessions spill KV to DRAM/SSD and
                                       resume byte-identically)
                  [--kv-spill-dram-mib M]  DRAM spill-area budget
                  [--preempt-cap C]    max preemptions per session (0
                                       disables preemption)
                  [--prefill-chunk N]  prompt tokens per scheduler turn
                  [--batch]            one shared per-layer pass for all
                                       co-resident sessions (union-plan
                                       cache reconciliation)
                  [--batch-kernel]     + stacked layer_step_batch HLO
                  [--no-continuous]    admit only at turn assembly (v2
                                       default admits into in-flight
                                       turns)
                  [--fault-read P] [--fault-write P] [--fault-torn P]
                  [--fault-flip P] [--fault-spike P] [--fault-spike-ms M]
                  [--fault-seed S]     seeded chaos: inject spill-path
                                       faults at the given per-op
                                       probabilities (self-healing:
                                       CRC + retries + recompute keep
                                       outputs byte-identical)
                  [--spill-retries N]  attempts per spill I/O op before
                                       the degradation ladder engages
                  [--pipeline]         pipelined datapath: speculative
                                       next-layer staging + overlapped
                                       KV restore (outputs stay
                                       byte-identical)
                  [--io-threads N]     SSD preloader / staging worker
                                       threads (default 1)
                  protocol v1: `GEN <max_new> <prompt>` or
                  `GEN@<class>[:<deadline_ms>] <max_new> <prompt>`
                  with class in {high, normal, batch}
                  protocol v2 (`HELLO v2` first): streamed
                  `ACK/TOK/END` frames, `CANCEL <id>` mid-decode,
                  typed `ERR <code> <id> <msg>`
  simulate        simulated large-model run: --model {7B,13B,40B,70B}
                  --in N --out N [--dram-gib G]
                  [--policy atu|lru|window|setassoc] (default: setassoc,
                  the cache_policy sweep winner)
                  [--capture-trace F] [--no-ssd] [--no-cache] [--no-mp]
  fleet           heterogeneous replica fleet on the virtual clock:
                  prefill lands on fast replicas, steady-state decode
                  drains to low-carbon ones via checksummed KV handoff
                  --gpus A100,M40,M40  one replica per name (gpu_db)
                  [--model 7B] [--requests N] [--seed S] [--slots K]
                  [--mix decode-heavy|prefill-heavy|steady|bursty]
                  [--arrival-scale X]  stretch trace inter-arrivals ×X
                  [--intensity G]      grid gCO2/kWh (default 820)
                  [--no-handoff]       sessions finish where they
                                       prefilled (ablation)
  experiment ID   regenerate a paper artifact: fig1 fig4 fig5 fig6 fig9
                  fig10 fig11 fig12 fig13 table14 alg1 cache_policy,
                  or `all`
  ratio-search    Algorithm 1 (uncertainty-guided mix search)
  carbon-report   Fig 1 + Fig 12 summary

FLAGS: --artifacts DIR   artifact directory (default: artifacts)
       --quick           smaller workloads for smoke runs
";

fn info(args: &Args) -> anyhow::Result<()> {
    let opts = opts_of(args);
    println!("m2cache {}", env!("CARGO_PKG_VERSION"));
    match m2cache::runtime::Runtime::new() {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e:#}"),
    }
    let art = Path::new(opts.artifacts);
    println!(
        "artifacts at {:?}: {}",
        art,
        if art.join("layer_step.hlo.txt").exists() {
            "present"
        } else {
            "MISSING (run `make artifacts`)"
        }
    );
    println!("\nmodel geometries:");
    for m in ["7B", "13B", "40B", "70B", "tiny"] {
        let s = ModelSpec::by_name(m).unwrap();
        println!(
            "  {:<12} layers={:<3} d={:<5} ffn={:<6} params={:.2}e9 fp16={:.1} GiB ffn-share={:.0}%",
            s.name,
            s.n_layers,
            s.d_model,
            s.ffn_hidden,
            s.total_params() as f64 / 1e9,
            s.fp16_bytes() as f64 / (1u64 << 30) as f64,
            s.ffn_fraction() * 100.0
        );
    }
    Ok(())
}

/// `generate --stream`: run the one request through the event-driven
/// serving core and print each token the tick it is produced — the CLI
/// face of the same `SessionEvent` stream protocol v2 serves.
fn generate_stream(args: &Args) -> anyhow::Result<()> {
    use std::io::Write as _;
    let opts = opts_of(args);
    let prompt_text = args.get_or("prompt", "the quick brown fox ");
    let n = args.get_usize("tokens", 48);
    let eng = ExecEngine::new(Path::new(opts.artifacts), engine_config(args))?;
    let mut core = ServingCore::from_engine(eng);
    core.submit(Request::new(1, tokenize(prompt_text), n));
    let start = std::time::Instant::now();
    let mut first_tok_s = None;
    let mut n_tokens = 0usize;
    print!("{prompt_text}");
    std::io::stdout().flush()?;
    while !core.is_idle() {
        for ev in core.pump(&mut || None) {
            match ev {
                SessionEvent::Token { token, .. } => {
                    first_tok_s.get_or_insert_with(|| start.elapsed().as_secs_f64());
                    n_tokens += 1;
                    print!("{}", detokenize(&[token]));
                    std::io::stdout().flush()?;
                }
                SessionEvent::Failed { error, .. } => anyhow::bail!(error),
                _ => {}
            }
        }
    }
    let dt = start.elapsed().as_secs_f64();
    let eng = core.into_engine();
    println!();
    println!(
        "tokens : {} in {:.2}s = {:.1} tok/s | first token {:.0} ms (streamed)",
        n_tokens,
        dt,
        n_tokens as f64 / dt.max(1e-9),
        first_tok_s.unwrap_or(0.0) * 1e3,
    );
    println!("telemetry: {}", eng.tel.to_json());
    Ok(())
}

fn generate(args: &Args) -> anyhow::Result<()> {
    if args.flag("stream") {
        return generate_stream(args);
    }
    let opts = opts_of(args);
    let prompt_text = args.get_or("prompt", "the quick brown fox ");
    let n = args.get_usize("tokens", 48);
    let mut eng = ExecEngine::new(Path::new(opts.artifacts), engine_config(args))?;
    if args.get("capture-trace").is_some() {
        eng.capture_plans();
    }
    let start = std::time::Instant::now();
    let out = eng.generate(&tokenize(prompt_text), n)?;
    let dt = start.elapsed().as_secs_f64();
    println!("prompt : {prompt_text:?}");
    println!("output : {:?}", detokenize(&out));
    println!(
        "tokens : {} in {:.2}s = {:.1} tok/s | ttft {:.0} ms | hbm-hit {:.0}% | pcie {}",
        out.len(),
        dt,
        out.len() as f64 / dt,
        eng.tel.ttft_s * 1e3,
        eng.tel.hit_ratio() * 100.0,
        m2cache::util::text::fmt_bytes(eng.tel.traffic.dram_to_hbm)
    );
    println!("telemetry: {}", eng.tel.to_json());
    if let Some(path) = args.get("capture-trace") {
        let trace = eng.take_captured_plans().expect("capture was enabled");
        trace.save(path)?;
        println!("captured {} plan records to {path}", trace.len());
    }
    Ok(())
}

fn serve(args: &Args) -> anyhow::Result<()> {
    let opts = opts_of(args);
    let addr = args.get_or("addr", "127.0.0.1:7433");
    let max = args.get("max-requests").map(|s| s.parse()).transpose()?;
    let cfg = engine_config(args);
    let sessions = cfg.max_sessions;
    let eng = ExecEngine::new(Path::new(opts.artifacts), cfg)?;
    println!(
        "serving tiny model, up to {sessions} interleaved session(s) \
         (v1: `GEN[@class[:deadline_ms]] <max_new> <prompt>`; \
         v2 after `HELLO v2`: streamed TOK/END frames + `CANCEL <id>`)"
    );
    let eng = m2cache::coordinator::server::serve(eng, addr, max, |a| {
        println!("listening on {a}");
    })?;
    println!("telemetry: {}", eng.tel.to_json());
    Ok(())
}

fn simulate(args: &Args) -> anyhow::Result<()> {
    let model = args.get_or("model", "13B");
    let spec = ModelSpec::by_name(model)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
    let inp = args.get_usize("in", 64);
    let outp = args.get_usize("out", 64);
    let gpu = m2cache::carbon::find_gpu(args.get_or("gpu", "RTX3090"))
        .ok_or_else(|| anyhow::anyhow!("unknown gpu"))?;
    let mut e = SimEngine::new(spec, HardwareSpec::rtx3090_testbed(), engine_config(args));
    if args.get("capture-trace").is_some() {
        e.capture_plans();
    }
    let r = e.run(inp, outp, gpu);
    println!(
        "{}: {:.3} tok/s | ttft {:.2}s | total {:.2}s (simulated)",
        e.spec.name, r.tokens_per_s, r.ttft_s, r.total_s
    );
    println!(
        "hbm-hit {:.0}% | dram peak {} | pcie {} | ssd {}",
        r.telemetry.hit_ratio() * 100.0,
        m2cache::util::text::fmt_bytes(r.telemetry.peak_dram_bytes),
        m2cache::util::text::fmt_bytes(r.telemetry.traffic.dram_to_hbm),
        m2cache::util::text::fmt_bytes(r.telemetry.traffic.ssd_to_dram),
    );
    println!(
        "carbon: {:.1} gCO2 total ({:.3} g/token)",
        r.carbon.total_g(),
        m2cache::carbon::g_per_token(&r.carbon, r.telemetry.tokens_generated)
    );
    if let Some(path) = args.get("capture-trace") {
        let trace = e.take_captured_plans().expect("capture was enabled");
        trace.save(path)?;
        println!("captured {} plan records to {path}", trace.len());
    }
    Ok(())
}

/// `fleet`: replay a seeded trace over heterogeneous replicas on the
/// virtual clock — the CLI face of `SimEngine::run_fleet` (carbon-aware
/// prefill/decode disaggregation with ticket-based KV handoff).
fn fleet(args: &Args) -> anyhow::Result<()> {
    use m2cache::coordinator::workload::{generate as gen_trace, Mix, TraceSpec};
    use m2cache::coordinator::FleetConfig;
    let model = args.get_or("model", "7B");
    let spec = ModelSpec::by_name(model)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
    let names = args.get_or("gpus", "A100,M40,M40");
    let mut gpus = Vec::new();
    for name in names.split(',').filter(|s| !s.trim().is_empty()) {
        let g = m2cache::carbon::find_gpu(name.trim())
            .ok_or_else(|| anyhow::anyhow!("unknown gpu {name}"))?;
        gpus.push(g);
    }
    anyhow::ensure!(!gpus.is_empty(), "--gpus names no replicas");
    let mix_name = args.get_or("mix", "decode-heavy");
    let mix = Mix::parse(mix_name).ok_or_else(|| anyhow::anyhow!("unknown mix {mix_name}"))?;
    let n = args.get_usize("requests", 32);
    let seed = args.get_u64("seed", 17);
    let slots = args.get_usize("slots", 8).max(1);
    let scale = args.get_u64("arrival-scale", 35).max(1);
    let mut events = gen_trace(&TraceSpec {
        mix,
        n,
        seed,
        vocab: spec.vocab as u32,
    });
    for ev in &mut events {
        ev.at_ms *= scale;
    }
    let fc = FleetConfig {
        intensity_g_per_kwh: args
            .get_f64("intensity", m2cache::carbon::PAPER_INTENSITY_G_PER_KWH),
        handoff: !args.flag("no-handoff"),
        ..FleetConfig::default()
    };
    let e = SimEngine::new(spec, HardwareSpec::rtx3090_testbed(), engine_config(args));
    let r = e.run_fleet(&gpus, slots, &events, fc)?;
    println!(
        "fleet[{}] {}: {} tokens in {:.2}s = {:.1} tok/s (virtual)",
        names,
        e.spec.name,
        r.tokens,
        r.makespan_ms / 1e3,
        r.tok_per_s
    );
    println!(
        "carbon {:.2} g = {:.3} mg/token | ttft p50 {:.0} ms p99 {:.0} ms | \
         handoffs {} ({} aborted, {} recovered, {} B moved)",
        r.gco2_g,
        r.gco2_mg_per_token,
        r.p50_ttft_ms,
        r.p99_ttft_ms,
        r.counters.handoffs,
        r.counters.handoff_aborts,
        r.counters.handoff_recoveries,
        r.counters.handoff_bytes,
    );
    for (i, row) in r.counters.live().iter().enumerate() {
        println!(
            "  replica {i} {:<8} prefill {:<6} decode {:<7} in/out {}/{} | {:.2} gCO2",
            row.gpu,
            row.prefill_turns,
            row.decode_turns,
            row.handoffs_in,
            row.handoffs_out,
            row.gco2_g
        );
    }
    Ok(())
}

fn experiment(args: &Args) -> anyhow::Result<()> {
    let opts = opts_of(args);
    let id = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    if id == "all" {
        for id in experiments::ALL {
            println!("==================== {id} ====================");
            match experiments::run(id, opts) {
                Ok(out) => println!("{out}"),
                Err(e) => println!("({id} skipped: {e:#})\n"),
            }
        }
        Ok(())
    } else {
        print!("{}", experiments::run(id, opts)?);
        Ok(())
    }
}
