//! The Fig 4 media study: end-to-end decode latency for the *same*
//! dense model with weights resident on HBM, DRAM, or SSD. The paper's
//! measured ratios — DRAM ≈ 10× HBM, SSD ≈ 85× HBM — calibrate the link
//! specs in `memsim::tier`.

use crate::memsim::{Channel, HardwareSpec, Link, SimClock};
use crate::model::spec::ModelSpec;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Medium {
    Hbm,
    Dram,
    Ssd,
}

impl Medium {
    pub fn name(self) -> &'static str {
        match self {
            Medium::Hbm => "HBM",
            Medium::Dram => "DRAM",
            Medium::Ssd => "SSD",
        }
    }
}

/// Per-token decode latency (seconds) with FFN weights on `medium`.
/// Attention stays HBM-resident in all cases (as in the paper's Fig 4
/// setup, which offloads FFNs).
pub fn media_decode_latency(spec: &ModelSpec, hw: &HardwareSpec, medium: Medium) -> f64 {
    let mut clock = SimClock::new();
    let ffn_bytes = 2 * spec.ffn_params_per_layer();
    let attn_bytes = 2 * spec.attn_params_per_layer();
    for _layer in 0..spec.n_layers {
        // Weight acquisition for this layer's FFN.
        let copy = match medium {
            Medium::Hbm => None,
            Medium::Dram => {
                let l = hw.links.get(Link::DramToHbm);
                Some(clock.submit(Channel::PcieH2d, l.time_s(ffn_bytes)))
            }
            Medium::Ssd => {
                let s = hw.links.get(Link::SsdToDram);
                let stage = clock.submit(Channel::Ssd, s.time_s(ffn_bytes));
                let l = hw.links.get(Link::DramToHbm);
                Some(clock.submit_after(Channel::PcieH2d, l.time_s(ffn_bytes), stage))
            }
        };
        // Attention compute (weights already in HBM).
        let t_attn = hw.gpu_time_s(2.0 * spec.attn_params_per_layer() as f64, attn_bytes);
        clock.run(Channel::Gpu, t_attn);
        if let Some(c) = copy {
            clock.join(c);
        }
        let t_ffn = hw.gpu_time_s(2.0 * spec.ffn_params_per_layer() as f64, ffn_bytes);
        clock.run(Channel::Gpu, t_ffn);
    }
    // Fixed per-token framework overhead (sampling, launches, host glue).
    clock.run(Channel::Cpu, hw.token_overhead_s);
    clock.now_s()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_ratios_match_paper_bands() {
        let spec = ModelSpec::llama2_7b();
        let hw = HardwareSpec::rtx3090_testbed();
        let hbm = media_decode_latency(&spec, &hw, Medium::Hbm);
        let dram = media_decode_latency(&spec, &hw, Medium::Dram);
        let ssd = media_decode_latency(&spec, &hw, Medium::Ssd);
        let r_dram = dram / hbm;
        let r_ssd = ssd / hbm;
        // Paper: DRAM ~10x HBM; SSD ~85x HBM (and ~8x DRAM).
        assert!((5.0..20.0).contains(&r_dram), "DRAM/HBM {r_dram:.1}");
        assert!((40.0..130.0).contains(&r_ssd), "SSD/HBM {r_ssd:.1}");
        assert!(
            (4.0..12.0).contains(&(ssd / dram)),
            "SSD/DRAM {:.1}",
            ssd / dram
        );
    }

    #[test]
    fn latency_scales_with_model_size() {
        let hw = HardwareSpec::rtx3090_testbed();
        let small = media_decode_latency(&ModelSpec::llama2_7b(), &hw, Medium::Dram);
        let big = media_decode_latency(&ModelSpec::llama2_13b(), &hw, Medium::Dram);
        assert!(big > 1.5 * small);
    }
}
