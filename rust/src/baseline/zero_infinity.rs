//! ZeRO-Inference (DeepSpeed "ZeRO-Infinity" offload) baseline: dense
//! FP16 weights streamed layer-by-layer to the GPU for *every* token,
//! with a one-layer prefetch pipeline. No sparsity, no quantization, no
//! neuron cache. When the model exceeds DRAM, the overflow fraction of
//! every layer must additionally traverse SSD→DRAM first — this is why
//! the paper measures ~0.02 tok/s for LLaMA-70B on a 64 GB host.

use crate::carbon::{self, CarbonBreakdown, GpuSpec, RunProfile};
use crate::coordinator::engine_sim::SimResult;
use crate::memsim::{Channel, HardwareSpec, Link, SimClock};
use crate::model::spec::ModelSpec;
use crate::telemetry::Telemetry;

pub struct ZeroInfinityEngine {
    pub spec: ModelSpec,
    pub hw: HardwareSpec,
    /// Host DRAM available for weight staging (bytes).
    pub dram_capacity: u64,
    clock: SimClock,
    kv_len: usize,
    pub tel: Telemetry,
}

impl ZeroInfinityEngine {
    pub fn new(spec: ModelSpec, hw: HardwareSpec, dram_capacity: u64) -> Self {
        ZeroInfinityEngine {
            spec,
            hw,
            dram_capacity,
            clock: SimClock::new(),
            kv_len: 0,
            tel: Telemetry::default(),
        }
    }

    /// FP16 bytes of one layer (attention + dense FFN — ZeRO streams
    /// the full layer).
    fn layer_bytes(&self) -> u64 {
        2 * (self.spec.ffn_params_per_layer() + self.spec.attn_params_per_layer())
    }

    /// Fraction of the model that exceeds DRAM and lives on SSD/NVMe.
    fn ssd_fraction(&self) -> f64 {
        let total = self.layer_bytes() * self.spec.n_layers as u64;
        if total <= self.dram_capacity {
            0.0
        } else {
            1.0 - self.dram_capacity as f64 / total as f64
        }
    }

    /// One full forward pass over all layers for `batch_tokens` tokens
    /// of compute (decode: 1; prefill: prompt length).
    fn full_pass(&mut self, batch_tokens: usize) {
        let lb = self.layer_bytes();
        let ssd_frac = self.ssd_fraction();
        let h2d = self.hw.links.get(Link::DramToHbm);
        let ssd = self.hw.links.get(Link::SsdToDram);
        for _layer in 0..self.spec.n_layers {
            // Prefetch pipeline: the copy of layer l is submitted ahead
            // and overlaps the previous layer's compute through channel
            // concurrency; the SSD-resident overflow must reach DRAM
            // first (submit_after chains the stages).
            let ssd_bytes = (lb as f64 * ssd_frac) as u64;
            let copy = if ssd_bytes > 0 {
                let stage = self.clock.submit(Channel::Ssd, ssd.time_s(ssd_bytes));
                self.tel.traffic.ssd_to_dram += ssd_bytes;
                self.clock
                    .submit_after(Channel::PcieH2d, h2d.time_s(lb), stage)
            } else {
                self.clock.submit(Channel::PcieH2d, h2d.time_s(lb))
            };
            self.tel.traffic.dram_to_hbm += lb;
            let flops = batch_tokens as f64
                * 2.0
                * (self.spec.ffn_params_per_layer() + self.spec.attn_params_per_layer())
                    as f64;
            let t = self.hw.gpu_time_s(flops, lb);
            self.clock.join(copy);
            let before = self.clock.now_s();
            self.clock.run(Channel::Gpu, t);
            self.tel.phases.ffn_s += self.clock.now_s() - before;
        }
        // Fixed per-token framework overhead (host glue + sampling).
        self.clock.run(Channel::Cpu, self.hw.token_overhead_s);
    }

    pub fn run(&mut self, prompt_len: usize, gen_tokens: usize, gpu: &GpuSpec) -> SimResult {
        self.full_pass(prompt_len); // prefill
        self.kv_len = prompt_len;
        self.tel.prefill_tokens = prompt_len as u64;
        let mut ttft = self.clock.now_s();
        let decode_start = self.clock.now_s();
        for i in 0..gen_tokens {
            self.full_pass(1);
            self.kv_len += 1;
            self.tel.tokens_generated += 1;
            if i == 0 {
                ttft = self.clock.now_s();
            }
        }
        let total_s = self.clock.now_s();
        self.tel.ttft_s = ttft;
        self.tel.peak_dram_bytes = self
            .dram_capacity
            .min(self.layer_bytes() * self.spec.n_layers as u64);
        let profile = RunProfile {
            wall_s: total_s,
            gpu_util: self.clock.utilization(Channel::Gpu),
            dram_gib: self.tel.peak_dram_bytes as f64 / (1u64 << 30) as f64,
            ssd_active: self.ssd_fraction() > 0.0,
            cpu_cores: 1.0,
        };
        let carbon: CarbonBreakdown =
            carbon::footprint(gpu, &profile, carbon::PAPER_INTENSITY_G_PER_KWH, false);
        let decode_s = total_s - decode_start;
        SimResult {
            total_s,
            ttft_s: ttft,
            tokens_per_s: if decode_s > 0.0 {
                gen_tokens as f64 / decode_s
            } else {
                0.0
            },
            telemetry: self.tel.clone(),
            carbon,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::find_gpu;

    fn run(spec: ModelSpec, dram_gib: u64) -> SimResult {
        let hw = HardwareSpec::rtx3090_testbed();
        let mut e = ZeroInfinityEngine::new(spec, hw, dram_gib << 30);
        e.run(16, 8, find_gpu("RTX3090").unwrap())
    }

    #[test]
    fn bandwidth_bound_decode_rate_7b() {
        // 7B fp16 ≈ 13 GB over a 16 GB/s PCIe link ⇒ ~1.2 tok/s ceiling.
        let r = run(ModelSpec::llama2_7b(), 64);
        assert!(
            (0.5..2.5).contains(&r.tokens_per_s),
            "7B ZeRO-Inf {} tok/s",
            r.tokens_per_s
        );
    }

    #[test]
    fn seventy_b_collapses_on_ssd_overflow() {
        // Paper: "~0.02 tokens per second" for 70B.
        let r = run(ModelSpec::llama2_70b(), 64);
        assert!(
            r.tokens_per_s < 0.08,
            "70B ZeRO-Inf {} tok/s",
            r.tokens_per_s
        );
        assert!(r.telemetry.traffic.ssd_to_dram > 0);
    }

    #[test]
    fn no_ssd_traffic_when_model_fits_dram() {
        let r = run(ModelSpec::llama2_7b(), 64);
        assert_eq!(r.telemetry.traffic.ssd_to_dram, 0);
    }

    #[test]
    fn streams_full_model_per_token() {
        let spec = ModelSpec::llama2_7b();
        let r = run(spec.clone(), 64);
        let per_pass =
            2 * (spec.ffn_params_per_layer() + spec.attn_params_per_layer())
                * spec.n_layers as u64;
        // prefill + 8 decode passes
        assert_eq!(r.telemetry.traffic.dram_to_hbm, per_pass * 9);
    }
}
