//! Baselines the paper compares against, implemented over the same
//! memory-hierarchy simulator as the M2Cache engine so ratios are
//! apples-to-apples:
//!
//! - [`zero_infinity`]: DeepSpeed ZeRO-Inference-style dense layer
//!   streaming (the paper's main comparator, Figs 9/12).
//! - [`media`]: the Fig 4 media study — identical dense decode with
//!   weights resident in HBM, DRAM, or SSD.

pub mod media;
pub mod zero_infinity;

pub use media::{media_decode_latency, Medium};
pub use zero_infinity::ZeroInfinityEngine;
