//! # M2Cache
//!
//! A full-system reproduction of *"Harnessing Your DRAM and SSD for
//! Sustainable and Accessible LLM Inference with Mixed-Precision and
//! Multi-level Caching"* (cs.LG 2024) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! - **L3 (this crate)** — the M2Cache coordinator: dynamic-sparse
//!   mixed-precision planning, the neuron-level HBM cache with the ATU
//!   policy, the two-level DRAM cache with pattern-aware SSD preloading,
//!   request serving, carbon accounting, and the ZeRO-Infinity-style
//!   baseline, all over a calibrated memory-hierarchy simulator *and* a
//!   real PJRT execution path.
//! - **L2/L1 (build-time Python)** — the JAX/Pallas model and kernels,
//!   AOT-lowered to `artifacts/*.hlo.txt`, loaded by [`runtime`].
//!
//! See `DESIGN.md` for the architecture and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod baseline;
pub mod cache;
pub mod carbon;
pub mod coordinator;
pub mod experiments;
pub mod memsim;
pub mod model;
pub mod precision;
pub mod runtime;
pub mod sparsity;
pub mod telemetry;
pub mod util;
