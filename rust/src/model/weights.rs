//! On-disk weight store — the "full model in SSD" of the paper's bottom
//! tier. Layout is neuron-major so a single neuron (gate row + up row +
//! down column) is one contiguous record per precision, which is what
//! the DRAM/HBM caches move around.
//!
//! The store is written either by `python/compile/gen_weights.py` (the
//! build-time path: trained tiny model + predictors) or by
//! [`WeightStore::create`] (rust-side generator used in tests). Both
//! produce identical *formats*; byte-level equality across languages is
//! not required because weights flow through the store only.
//!
//! File layout under `<dir>/`:
//! ```text
//! meta.cfg            key = value (name, dims, seed, int4_group, rank)
//! embed.f32           vocab*d f32 LE (tied LM head)
//! final_norm.f32      d f32
//! layer<i>.attn.f32   wq(d*d) wk(d*kv) wv(d*kv) wo(d*d) ln1(d) ln2(d)
//! layer<i>.ffn.fp16   per neuron: 3d u16 (gate row, up row, down col)
//! layer<i>.ffn.int8   per neuron: scale f32 + 3d i8
//! layer<i>.ffn.int4   per neuron: ceil(3d/G) f32 scales + ceil(3d/2) packed
//! predictor<i>.f32    A(d*r) f32 then B(r*n_ffn) f32
//! ```

use crate::model::spec::ModelSpec;
use crate::precision::{f16, quant, Dtype};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::fs;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

/// Default INT4 quantization group.
pub const INT4_GROUP: usize = 64;
/// Default predictor rank.
pub const PREDICTOR_RANK: usize = 16;

#[derive(Debug, Clone)]
pub struct WeightStore {
    pub dir: PathBuf,
    pub spec: ModelSpec,
    pub int4_group: usize,
    pub rank: usize,
}

/// Attention + norm weights of one layer, dequantized.
#[derive(Debug, Clone)]
pub struct AttnWeights {
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
}

/// Low-rank predictor factors of one layer.
#[derive(Debug, Clone)]
pub struct PredictorWeights {
    pub a: Vec<f32>, // d x r
    pub b: Vec<f32>, // r x n_ffn
    pub rank: usize,
}

impl WeightStore {
    // ---------- record geometry ----------

    /// f32 values in one neuron record (gate + up + down).
    pub fn neuron_values(&self) -> usize {
        self.spec.values_per_neuron()
    }

    /// On-disk record size per neuron for a precision.
    pub fn record_bytes(&self, dtype: Dtype) -> usize {
        let v = self.neuron_values();
        match dtype {
            Dtype::F32 => 4 * v,
            Dtype::F16 => 2 * v,
            Dtype::Int8 => 4 + v,
            Dtype::Int4 => 4 * v.div_ceil(self.int4_group) + v.div_ceil(2),
        }
    }

    fn ffn_path(&self, layer: usize, dtype: Dtype) -> PathBuf {
        let ext = match dtype {
            Dtype::F32 => "f32",
            Dtype::F16 => "fp16",
            Dtype::Int8 => "int8",
            Dtype::Int4 => "int4",
        };
        self.dir.join(format!("layer{layer}.ffn.{ext}"))
    }

    // ---------- creation (rust-side generator, used by tests) ----------

    /// Generate a complete store with random weights. The FFN master
    /// weights are N(0, 1/sqrt(d)); quantized variants are derived from
    /// the same master values so precision comparisons are meaningful.
    pub fn create(dir: &Path, spec: &ModelSpec, seed: u64) -> Result<WeightStore> {
        fs::create_dir_all(dir)?;
        let store = WeightStore {
            dir: dir.to_path_buf(),
            spec: spec.clone(),
            int4_group: INT4_GROUP,
            rank: PREDICTOR_RANK,
        };
        let d = spec.d_model;
        let scale = 1.0 / (d as f64).sqrt();
        let mut rng = Rng::new(seed);
        let gen = |rng: &mut Rng, n: usize| -> Vec<f32> {
            (0..n).map(|_| (rng.normal() * scale) as f32).collect()
        };

        // Embeddings + final norm.
        write_f32(&store.dir.join("embed.f32"), &gen(&mut rng, spec.vocab * d))?;
        write_f32(&store.dir.join("final_norm.f32"), &vec![1.0f32; d])?;

        let head_dim = d / spec.n_heads;
        let kv_dim = head_dim * spec.n_kv_heads;
        for l in 0..spec.n_layers {
            // Attention block.
            let mut attn = Vec::new();
            attn.extend(gen(&mut rng, d * d)); // wq
            attn.extend(gen(&mut rng, d * kv_dim)); // wk
            attn.extend(gen(&mut rng, d * kv_dim)); // wv
            attn.extend(gen(&mut rng, d * d)); // wo
            attn.extend(vec![1.0f32; d]); // ln1
            attn.extend(vec![1.0f32; d]); // ln2
            write_f32(&store.dir.join(format!("layer{l}.attn.f32")), &attn)?;

            // FFN: generate master values neuron-major, then derive the
            // three precision files.
            let v = store.neuron_values();
            let mut fp16_bytes = Vec::with_capacity(spec.ffn_hidden * 2 * v);
            let mut int8_bytes = Vec::new();
            let mut int4_bytes = Vec::new();
            for _ in 0..spec.ffn_hidden {
                let master = gen(&mut rng, v);
                f16::encode_slice(&master, &mut fp16_bytes);
                let b8 = quant::quantize_int8(&master);
                int8_bytes.extend_from_slice(&b8.scale.to_le_bytes());
                int8_bytes.extend(b8.q.iter().map(|&q| q as u8));
                let b4 = quant::quantize_int4(&master, store.int4_group);
                for s in &b4.scales {
                    int4_bytes.extend_from_slice(&s.to_le_bytes());
                }
                int4_bytes.extend_from_slice(&b4.packed);
            }
            fs::write(store.ffn_path(l, Dtype::F16), &fp16_bytes)?;
            fs::write(store.ffn_path(l, Dtype::Int8), &int8_bytes)?;
            fs::write(store.ffn_path(l, Dtype::Int4), &int4_bytes)?;

            // Random low-rank predictor (tests exercise plumbing only;
            // the build-time python predictor is trained on activations).
            let mut pred = Vec::new();
            pred.extend(gen(&mut rng, d * store.rank));
            pred.extend(gen(&mut rng, store.rank * spec.ffn_hidden));
            write_f32(&store.dir.join(format!("predictor{l}.f32")), &pred)?;
        }

        // Metadata last: its presence marks a complete store.
        let meta = format!(
            "name = {}\nfamily = {}\nn_layers = {}\nd_model = {}\nffn_hidden = {}\n\
             n_heads = {}\nn_kv_heads = {}\nvocab = {}\nint4_group = {}\nrank = {}\nseed = {}\n",
            spec.name,
            match spec.family {
                crate::model::spec::Family::LlamaReglu => "llama_reglu",
                crate::model::spec::Family::Falcon => "falcon",
            },
            spec.n_layers,
            spec.d_model,
            spec.ffn_hidden,
            spec.n_heads,
            spec.n_kv_heads,
            spec.vocab,
            store.int4_group,
            store.rank,
            seed
        );
        fs::write(store.dir.join("meta.cfg"), meta)?;
        Ok(store)
    }

    /// Open an existing store and validate its geometry.
    pub fn open(dir: &Path) -> Result<WeightStore> {
        let meta_text = fs::read_to_string(dir.join("meta.cfg"))
            .with_context(|| format!("no weight store at {}", dir.display()))?;
        let meta = crate::util::text::parse_config(&meta_text);
        let get = |k: &str| -> Result<String> {
            meta.get(k)
                .cloned()
                .with_context(|| format!("meta.cfg missing key {k}"))
        };
        let parse = |k: &str| -> Result<usize> {
            Ok(get(k)?.parse::<usize>().with_context(|| format!("bad {k}"))?)
        };
        let family = match get("family")?.as_str() {
            "llama_reglu" => crate::model::spec::Family::LlamaReglu,
            "falcon" => crate::model::spec::Family::Falcon,
            other => bail!("unknown family {other}"),
        };
        let spec = ModelSpec {
            name: get("name")?,
            family,
            n_layers: parse("n_layers")?,
            d_model: parse("d_model")?,
            ffn_hidden: parse("ffn_hidden")?,
            n_heads: parse("n_heads")?,
            n_kv_heads: parse("n_kv_heads")?,
            vocab: parse("vocab")?,
        };
        let store = WeightStore {
            dir: dir.to_path_buf(),
            spec,
            int4_group: parse("int4_group")?,
            rank: parse("rank")?,
        };
        store.validate()?;
        Ok(store)
    }

    /// Check every expected file exists with the expected size.
    pub fn validate(&self) -> Result<()> {
        let d = self.spec.d_model;
        let expect = |p: PathBuf, bytes: u64| -> Result<()> {
            let len = fs::metadata(&p)
                .with_context(|| format!("missing {}", p.display()))?
                .len();
            if len != bytes {
                bail!("{}: {} bytes, expected {}", p.display(), len, bytes);
            }
            Ok(())
        };
        expect(
            self.dir.join("embed.f32"),
            (self.spec.vocab * d * 4) as u64,
        )?;
        expect(self.dir.join("final_norm.f32"), (d * 4) as u64)?;
        let head_dim = d / self.spec.n_heads;
        let kv_dim = head_dim * self.spec.n_kv_heads;
        let attn_vals = 2 * d * d + 2 * d * kv_dim + 2 * d;
        for l in 0..self.spec.n_layers {
            expect(
                self.dir.join(format!("layer{l}.attn.f32")),
                (attn_vals * 4) as u64,
            )?;
            for dt in [Dtype::F16, Dtype::Int8, Dtype::Int4] {
                expect(
                    self.ffn_path(l, dt),
                    (self.spec.ffn_hidden * self.record_bytes(dt)) as u64,
                )?;
            }
            expect(
                self.dir.join(format!("predictor{l}.f32")),
                ((d * self.rank + self.rank * self.spec.ffn_hidden) * 4) as u64,
            )?;
        }
        Ok(())
    }

    // ---------- reads (the "SSD" of the executed path) ----------

    /// Read one neuron's raw record bytes at a precision — the unit the
    /// caches transfer.
    pub fn read_neuron_raw(
        &self,
        layer: usize,
        neuron: u32,
        dtype: Dtype,
    ) -> Result<Vec<u8>> {
        let rec = self.record_bytes(dtype);
        let mut f = fs::File::open(self.ffn_path(layer, dtype))?;
        f.seek(SeekFrom::Start(neuron as u64 * rec as u64))?;
        let mut buf = vec![0u8; rec];
        f.read_exact(&mut buf)?;
        Ok(buf)
    }

    /// Read a contiguous *range* of neuron records (layer-wise preload).
    pub fn read_neuron_range_raw(
        &self,
        layer: usize,
        start: u32,
        count: usize,
        dtype: Dtype,
    ) -> Result<Vec<u8>> {
        let rec = self.record_bytes(dtype);
        let mut f = fs::File::open(self.ffn_path(layer, dtype))?;
        f.seek(SeekFrom::Start(start as u64 * rec as u64))?;
        let mut buf = vec![0u8; rec * count];
        f.read_exact(&mut buf)?;
        Ok(buf)
    }

    /// Dequantize a raw neuron record into f32 values.
    pub fn dequantize_record(&self, raw: &[u8], dtype: Dtype) -> Vec<f32> {
        let v = self.neuron_values();
        let mut out = Vec::with_capacity(v);
        match dtype {
            Dtype::F32 => {
                for ch in raw.chunks_exact(4) {
                    out.push(f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
                }
            }
            Dtype::F16 => f16::decode_slice(raw, &mut out),
            Dtype::Int8 => {
                let scale = f32::from_le_bytes([raw[0], raw[1], raw[2], raw[3]]);
                out.extend(raw[4..4 + v].iter().map(|&b| b as i8 as f32 * scale));
            }
            Dtype::Int4 => {
                let n_groups = v.div_ceil(self.int4_group);
                let mut scales = Vec::with_capacity(n_groups);
                for g in 0..n_groups {
                    let o = 4 * g;
                    scales.push(f32::from_le_bytes([
                        raw[o],
                        raw[o + 1],
                        raw[o + 2],
                        raw[o + 3],
                    ]));
                }
                let packed = &raw[4 * n_groups..];
                let block = quant::Int4Block {
                    group: self.int4_group,
                    scales,
                    packed: packed.to_vec(),
                    len: v,
                };
                quant::dequantize_int4(&block, &mut out);
            }
        }
        out
    }

    /// Read + dequantize one neuron.
    pub fn read_neuron(&self, layer: usize, neuron: u32, dtype: Dtype) -> Result<Vec<f32>> {
        let raw = self.read_neuron_raw(layer, neuron, dtype)?;
        Ok(self.dequantize_record(&raw, dtype))
    }

    pub fn read_attn(&self, layer: usize) -> Result<AttnWeights> {
        let d = self.spec.d_model;
        let head_dim = d / self.spec.n_heads;
        let kv_dim = head_dim * self.spec.n_kv_heads;
        let all = read_f32(&self.dir.join(format!("layer{layer}.attn.f32")))?;
        let mut off = 0;
        let mut take = |n: usize| {
            let s = all[off..off + n].to_vec();
            off += n;
            s
        };
        Ok(AttnWeights {
            wq: take(d * d),
            wk: take(d * kv_dim),
            wv: take(d * kv_dim),
            wo: take(d * d),
            ln1: take(d),
            ln2: take(d),
        })
    }

    pub fn read_embed(&self) -> Result<Vec<f32>> {
        read_f32(&self.dir.join("embed.f32"))
    }

    pub fn read_final_norm(&self) -> Result<Vec<f32>> {
        read_f32(&self.dir.join("final_norm.f32"))
    }

    pub fn read_predictor(&self, layer: usize) -> Result<PredictorWeights> {
        let d = self.spec.d_model;
        let all = read_f32(&self.dir.join(format!("predictor{layer}.f32")))?;
        let a_len = d * self.rank;
        Ok(PredictorWeights {
            a: all[..a_len].to_vec(),
            b: all[a_len..].to_vec(),
            rank: self.rank,
        })
    }

    /// Total on-disk bytes of the store (the "SSD footprint").
    pub fn disk_bytes(&self) -> Result<u64> {
        let mut total = 0;
        for entry in fs::read_dir(&self.dir)? {
            total += entry?.metadata()?.len();
        }
        Ok(total)
    }
}

fn write_f32(path: &Path, vals: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    fs::write(path, bytes)?;
    Ok(())
}

fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes = fs::read(path).with_context(|| format!("read {}", path.display()))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "f32 file with odd length");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("m2cache-test-{name}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        p
    }

    fn tiny_store(name: &str) -> WeightStore {
        let dir = tmpdir(name);
        WeightStore::create(&dir, &ModelSpec::tiny(), 42).unwrap()
    }

    #[test]
    fn create_open_roundtrip() {
        let s = tiny_store("roundtrip");
        let reopened = WeightStore::open(&s.dir).unwrap();
        assert_eq!(reopened.spec.d_model, 128);
        assert_eq!(reopened.spec.n_layers, 4);
        assert_eq!(reopened.int4_group, INT4_GROUP);
        fs::remove_dir_all(&s.dir).unwrap();
    }

    #[test]
    fn record_sizes() {
        let s = tiny_store("recsize");
        let v = 3 * 128;
        assert_eq!(s.record_bytes(Dtype::F16), 2 * v);
        assert_eq!(s.record_bytes(Dtype::Int8), 4 + v);
        assert_eq!(s.record_bytes(Dtype::Int4), 4 * 6 + v / 2);
        fs::remove_dir_all(&s.dir).unwrap();
    }

    #[test]
    fn precision_ladder_error_ordering() {
        // Reading the same neuron at fp16/int8/int4 must give decreasing
        // fidelity vs fp16 (the master's closest representation).
        let s = tiny_store("ladder");
        let hi = s.read_neuron(1, 7, Dtype::F16).unwrap();
        let med = s.read_neuron(1, 7, Dtype::Int8).unwrap();
        let lo = s.read_neuron(1, 7, Dtype::Int4).unwrap();
        let err = |a: &[f32], b: &[f32]| -> f64 {
            a.iter()
                .zip(b)
                .map(|(&x, &y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let e8 = err(&hi, &med);
        let e4 = err(&hi, &lo);
        assert!(e8 > 0.0 && e4 > e8, "int8 err {e8}, int4 err {e4}");
        fs::remove_dir_all(&s.dir).unwrap();
    }

    #[test]
    fn neuron_range_read_matches_single_reads() {
        let s = tiny_store("range");
        let range = s.read_neuron_range_raw(0, 5, 3, Dtype::Int8).unwrap();
        let rec = s.record_bytes(Dtype::Int8);
        for i in 0..3 {
            let single = s.read_neuron_raw(0, 5 + i as u32, Dtype::Int8).unwrap();
            assert_eq!(&range[i * rec..(i + 1) * rec], &single[..]);
        }
        fs::remove_dir_all(&s.dir).unwrap();
    }

    #[test]
    fn attn_weights_shapes() {
        let s = tiny_store("attn");
        let a = s.read_attn(2).unwrap();
        assert_eq!(a.wq.len(), 128 * 128);
        assert_eq!(a.wk.len(), 128 * 128); // n_kv_heads == n_heads for tiny
        assert_eq!(a.ln1.len(), 128);
        fs::remove_dir_all(&s.dir).unwrap();
    }

    #[test]
    fn predictor_shapes() {
        let s = tiny_store("pred");
        let p = s.read_predictor(0).unwrap();
        assert_eq!(p.a.len(), 128 * PREDICTOR_RANK);
        assert_eq!(p.b.len(), PREDICTOR_RANK * 512);
        fs::remove_dir_all(&s.dir).unwrap();
    }

    #[test]
    fn validate_catches_truncation() {
        let s = tiny_store("truncate");
        let path = s.dir.join("layer0.ffn.int8");
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() - 1]).unwrap();
        assert!(s.validate().is_err());
        fs::remove_dir_all(&s.dir).unwrap();
    }

    #[test]
    fn disk_bytes_positive_and_dominated_by_ffn() {
        let s = tiny_store("disk");
        let total = s.disk_bytes().unwrap();
        let ffn_fp16: u64 = (0..4)
            .map(|l| fs::metadata(s.ffn_path(l, Dtype::F16)).unwrap().len())
            .sum();
        assert!(total > ffn_fp16);
        assert!(ffn_fp16 > 0);
        fs::remove_dir_all(&s.dir).unwrap();
    }
}
