//! Model geometry specs and the on-disk weight store ("full model in
//! SSD", the bottom tier of the paper's hierarchy).

pub mod spec;
pub mod weights;

pub use spec::{Family, ModelSpec};
pub use weights::{AttnWeights, PredictorWeights, WeightStore, INT4_GROUP, PREDICTOR_RANK};
