//! Model geometry specifications.
//!
//! Simulated experiments need only the *geometry* of each model (layer
//! counts, widths ⇒ bytes per neuron, FLOPs per token); the executed
//! end-to-end path uses the `tiny` spec with real weights. A "neuron"
//! follows the paper's definition: one row of the FFN's first projection
//! and the matching column of the second (for gated ReGLU FFNs, the
//! gate row + up row + down column ⇒ `3 * d_model` values per neuron).

/// Architecture family; affects FFN shape and attention layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// LLaMA-style: gated ReGLU FFN (gate, up, down).
    LlamaReglu,
    /// Falcon-style: plain GELU/ReLU MLP (up, down) with parallel attn.
    Falcon,
}

#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub family: Family,
    pub n_layers: usize,
    pub d_model: usize,
    /// FFN hidden width = neurons per layer.
    pub ffn_hidden: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub vocab: usize,
}

impl ModelSpec {
    pub fn llama2_7b() -> ModelSpec {
        ModelSpec {
            name: "LLaMA-7B".into(),
            family: Family::LlamaReglu,
            n_layers: 32,
            d_model: 4096,
            ffn_hidden: 11008,
            n_heads: 32,
            n_kv_heads: 32,
            vocab: 32000,
        }
    }

    pub fn llama2_13b() -> ModelSpec {
        ModelSpec {
            name: "LLaMA-13B".into(),
            family: Family::LlamaReglu,
            n_layers: 40,
            d_model: 5120,
            ffn_hidden: 13824,
            n_heads: 40,
            n_kv_heads: 40,
            vocab: 32000,
        }
    }

    pub fn llama2_70b() -> ModelSpec {
        ModelSpec {
            name: "LLaMA-70B".into(),
            family: Family::LlamaReglu,
            n_layers: 80,
            d_model: 8192,
            ffn_hidden: 28672,
            n_heads: 64,
            n_kv_heads: 8,
            vocab: 32000,
        }
    }

    pub fn falcon_40b() -> ModelSpec {
        ModelSpec {
            name: "Falcon-40B".into(),
            family: Family::Falcon,
            n_layers: 60,
            d_model: 8192,
            ffn_hidden: 32768,
            n_heads: 128,
            n_kv_heads: 8,
            vocab: 65024,
        }
    }

    /// The executed end-to-end model: 4-layer byte-vocab LLaMA-ReGLU,
    /// ~1.2 M parameters, generated deterministically at build time.
    pub fn tiny() -> ModelSpec {
        ModelSpec {
            name: "tiny-1M".into(),
            family: Family::LlamaReglu,
            n_layers: 4,
            d_model: 128,
            ffn_hidden: 512,
            n_heads: 4,
            n_kv_heads: 4,
            vocab: 256,
        }
    }

    pub fn by_name(name: &str) -> Option<ModelSpec> {
        match name.to_ascii_lowercase().as_str() {
            "llama-7b" | "7b" => Some(Self::llama2_7b()),
            "llama-13b" | "13b" => Some(Self::llama2_13b()),
            "llama-70b" | "70b" => Some(Self::llama2_70b()),
            "falcon-40b" | "40b" => Some(Self::falcon_40b()),
            "tiny" | "tiny-1m" => Some(Self::tiny()),
            _ => None,
        }
    }

    /// Values per neuron: 3·d for gated FFNs, 2·d otherwise.
    pub fn values_per_neuron(&self) -> usize {
        match self.family {
            Family::LlamaReglu => 3 * self.d_model,
            Family::Falcon => 2 * self.d_model,
        }
    }

    /// FFN parameter count per layer.
    pub fn ffn_params_per_layer(&self) -> u64 {
        self.ffn_hidden as u64 * self.values_per_neuron() as u64
    }

    /// Attention parameter count per layer (q,k,v,o with GQA).
    pub fn attn_params_per_layer(&self) -> u64 {
        let d = self.d_model as u64;
        let head_dim = d / self.n_heads as u64;
        let kv_dim = head_dim * self.n_kv_heads as u64;
        // Wq: d*d, Wk: d*kv, Wv: d*kv, Wo: d*d
        2 * d * d + 2 * d * kv_dim
    }

    /// Total parameters (incl. embeddings + lm head, untied).
    pub fn total_params(&self) -> u64 {
        let per_layer = self.ffn_params_per_layer() + self.attn_params_per_layer();
        per_layer * self.n_layers as u64
            + 2 * (self.vocab as u64 * self.d_model as u64)
    }

    /// Fraction of parameters living in FFNs (paper: 63.99 % for 7B,
    /// 72.41 % for 70B).
    pub fn ffn_fraction(&self) -> f64 {
        (self.ffn_params_per_layer() * self.n_layers as u64) as f64
            / self.total_params() as f64
    }

    /// FLOPs for one decode token with `active` FFN neurons per layer
    /// (2 FLOPs per weight element, attention over `kv_len` cached keys).
    pub fn flops_per_token(&self, active_neurons: usize, kv_len: usize) -> f64 {
        let d = self.d_model as f64;
        let head_dim = d / self.n_heads as f64;
        let kv_dim = head_dim * self.n_kv_heads as f64;
        let attn_proj = 2.0 * (2.0 * d * d + 2.0 * d * kv_dim);
        let attn_scores = 2.0 * 2.0 * self.n_heads as f64 * head_dim * kv_len as f64;
        let ffn = 2.0 * active_neurons as f64 * self.values_per_neuron() as f64;
        (attn_proj + attn_scores + ffn) * self.n_layers as f64
            + 2.0 * d * self.vocab as f64
    }

    /// FP16 bytes of the whole model.
    pub fn fp16_bytes(&self) -> u64 {
        2 * self.total_params()
    }

    /// FP16 bytes of one full FFN layer.
    pub fn ffn_layer_bytes_fp16(&self) -> u64 {
        2 * self.ffn_params_per_layer()
    }

    /// KV-cache bytes per token (FP16).
    pub fn kv_bytes_per_token(&self) -> u64 {
        let head_dim = self.d_model / self.n_heads;
        (2 * self.n_layers * self.n_kv_heads * head_dim * 2) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_public_numbers() {
        // Within 5% of the nominal sizes.
        let close = |spec: ModelSpec, nominal: f64| {
            let p = spec.total_params() as f64;
            let rel = (p - nominal).abs() / nominal;
            assert!(rel < 0.05, "{}: {p:.3e} vs {nominal:.3e}", spec.name);
        };
        close(ModelSpec::llama2_7b(), 6.74e9);
        close(ModelSpec::llama2_13b(), 13.0e9);
        close(ModelSpec::llama2_70b(), 69.0e9);
        close(ModelSpec::falcon_40b(), 41.0e9);
    }

    #[test]
    fn ffn_fraction_matches_paper() {
        // Paper §2.1 cites 63.99 % (7B) — ours matches — and 72.41 %
        // (70B); counting gate+up+down against GQA attention, 70B's
        // actual gated-FFN share is ~0.82 (the paper likely counts only
        // up+down). The claim under test is the *shape*: FFN dominates
        // and its share grows with model size.
        let f7 = ModelSpec::llama2_7b().ffn_fraction();
        let f70 = ModelSpec::llama2_70b().ffn_fraction();
        assert!((0.60..0.68).contains(&f7), "7B ffn fraction {f7}");
        assert!((0.70..0.86).contains(&f70), "70B ffn fraction {f70}");
        assert!(f70 > f7, "fraction grows with model size");
    }

    #[test]
    fn seven_b_doesnt_fit_24gb_with_activations_13b_doesnt_fit_at_all() {
        // Motivation numbers: 13B fp16 > 24 GB HBM.
        let hbm = 24u64 << 30;
        assert!(ModelSpec::llama2_13b().fp16_bytes() > hbm);
        // 70B fp16 (~128-140 GB) exceeds HBM+DRAM (24+64 GB).
        assert!(ModelSpec::llama2_70b().fp16_bytes() > (24u64 + 64) << 30);
    }

    #[test]
    fn flops_per_token_magnitude() {
        // LLaMA-7B ≈ 2 * params ≈ 13.5 GFLOPs/token dense (paper cites
        // ~19.6 GFLOPs incl. overheads; same order).
        let spec = ModelSpec::llama2_7b();
        let f = spec.flops_per_token(spec.ffn_hidden, 128);
        assert!(
            (1.0e10..2.5e10).contains(&f),
            "7B flops/token {f:.3e}"
        );
    }

    #[test]
    fn sparsity_reduces_flops() {
        let spec = ModelSpec::llama2_7b();
        let dense = spec.flops_per_token(spec.ffn_hidden, 64);
        let sparse = spec.flops_per_token(spec.ffn_hidden / 10, 64);
        assert!(sparse < 0.6 * dense);
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(ModelSpec::by_name("13B").unwrap().n_layers, 40);
        assert_eq!(ModelSpec::by_name("tiny").unwrap().d_model, 128);
        assert!(ModelSpec::by_name("gpt-5").is_none());
    }

    #[test]
    fn values_per_neuron_by_family() {
        assert_eq!(ModelSpec::tiny().values_per_neuron(), 3 * 128);
        assert_eq!(ModelSpec::falcon_40b().values_per_neuron(), 2 * 8192);
    }

    #[test]
    fn kv_bytes_per_token() {
        let spec = ModelSpec::llama2_7b();
        // 2 (k,v) * 32 layers * 4096 dim * 2 bytes = 512 KiB/token.
        assert_eq!(spec.kv_bytes_per_token(), 512 << 10);
    }
}
