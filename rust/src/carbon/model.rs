//! Carbon accounting (paper §2.2, Formula 1, and the Fig 13 caption's
//! constants): total footprint = embodied share + operational emissions.
//!
//!   OCE = Σ_component power(W) × busy_time(h) × intensity(gCO2/kWh)
//!   ECE = embodied_kg × (runtime / lifespan)
//!
//! Component powers follow the paper: DRAM 26 W per 256 GiB (GreenDIMM),
//! SSD 2 W, GPU at TDP scaled by utilization.

use crate::carbon::gpu_db::GpuSpec;

/// Grid carbon intensity used throughout the paper's evaluation.
pub const PAPER_INTENSITY_G_PER_KWH: f64 = 820.0;
/// DRAM power per GiB (26 W / 256 GiB).
pub const DRAM_W_PER_GIB: f64 = 26.0 / 256.0;
/// SSD active power.
pub const SSD_W: f64 = 2.0;
/// Host CPU share attributed to cache management (paper pins 1 core).
pub const CPU_CORE_W: f64 = 12.0;
/// Assumed device lifespan for embodied amortization (5 years, ACT).
pub const LIFESPAN_HOURS: f64 = 5.0 * 365.0 * 24.0;

/// Activity profile of one inference run, produced by the engine's
/// telemetry and consumed here.
#[derive(Debug, Clone, Copy, Default)]
pub struct RunProfile {
    /// Wall-clock duration of the run, seconds.
    pub wall_s: f64,
    /// GPU busy fraction in [0,1] (compute + HBM traffic).
    pub gpu_util: f64,
    /// Peak DRAM working set attributed to the run, GiB.
    pub dram_gib: f64,
    /// Whether the SSD tier was active at all.
    pub ssd_active: bool,
    /// CPU cores dedicated to cache management.
    pub cpu_cores: f64,
}

/// Carbon breakdown in grams CO2e.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CarbonBreakdown {
    pub gpu_oce_g: f64,
    pub dram_oce_g: f64,
    pub ssd_oce_g: f64,
    pub cpu_oce_g: f64,
    pub embodied_g: f64,
}

impl CarbonBreakdown {
    pub fn operational_g(&self) -> f64 {
        self.gpu_oce_g + self.dram_oce_g + self.ssd_oce_g + self.cpu_oce_g
    }

    pub fn total_g(&self) -> f64 {
        self.operational_g() + self.embodied_g
    }
}

/// Compute the carbon footprint of a run on `gpu` at `intensity`
/// (gCO2/kWh). `include_embodied=false` models the paper's "existing
/// old-fashioned hardware incurs no additional embodied emissions"
/// argument (§3.2 Opportunity 1).
pub fn footprint(
    gpu: &GpuSpec,
    profile: &RunProfile,
    intensity: f64,
    include_embodied: bool,
) -> CarbonBreakdown {
    let hours = profile.wall_s / 3600.0;
    let kwh = |watts: f64| watts * hours / 1000.0;
    CarbonBreakdown {
        gpu_oce_g: kwh(gpu.tdp_w * profile.gpu_util.clamp(0.0, 1.0)) * intensity,
        dram_oce_g: kwh(DRAM_W_PER_GIB * profile.dram_gib) * intensity,
        ssd_oce_g: if profile.ssd_active {
            kwh(SSD_W) * intensity
        } else {
            0.0
        },
        cpu_oce_g: kwh(CPU_CORE_W * profile.cpu_cores) * intensity,
        embodied_g: if include_embodied {
            gpu.embodied_kg * 1000.0 * (hours / LIFESPAN_HOURS)
        } else {
            0.0
        },
    }
}

/// Grams CO2e per generated token, the per-request metric of Fig 12.
pub fn g_per_token(breakdown: &CarbonBreakdown, tokens: u64) -> f64 {
    if tokens == 0 {
        0.0
    } else {
        breakdown.total_g() / tokens as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::gpu_db::find;

    fn profile_1h() -> RunProfile {
        RunProfile {
            wall_s: 3600.0,
            gpu_util: 1.0,
            dram_gib: 256.0,
            ssd_active: true,
            cpu_cores: 1.0,
        }
    }

    #[test]
    fn one_hour_at_tdp_matches_hand_math() {
        let gpu = find("RTX3090").unwrap();
        let b = footprint(gpu, &profile_1h(), 820.0, false);
        assert!((b.gpu_oce_g - 287.0).abs() < 1e-6); // 0.35 kWh * 820
        assert!((b.dram_oce_g - 26.0 * 0.82).abs() < 1e-6); // 26 W -> 0.026 kWh
        assert!((b.ssd_oce_g - 2.0 * 0.82).abs() < 1e-6);
        assert_eq!(b.embodied_g, 0.0);
    }

    #[test]
    fn embodied_amortization() {
        let gpu = find("A100").unwrap();
        let b = footprint(gpu, &profile_1h(), 820.0, true);
        // 150 kg over 5y: 1 hour is 150_000 / 43800 g ≈ 3.42 g.
        assert!((b.embodied_g - 150_000.0 / LIFESPAN_HOURS).abs() < 1e-6);
        assert!(b.total_g() > b.operational_g());
    }

    #[test]
    fn idle_gpu_emits_nothing_operationally() {
        let gpu = find("RTX3090").unwrap();
        let p = RunProfile {
            wall_s: 3600.0,
            gpu_util: 0.0,
            dram_gib: 0.0,
            ssd_active: false,
            cpu_cores: 0.0,
        };
        let b = footprint(gpu, &p, 820.0, false);
        assert_eq!(b.operational_g(), 0.0);
    }

    #[test]
    fn per_token_metric() {
        let gpu = find("RTX3090").unwrap();
        let b = footprint(gpu, &profile_1h(), 820.0, false);
        let g = g_per_token(&b, 1000);
        assert!(g > 0.0);
        assert_eq!(g_per_token(&b, 0), 0.0);
    }

    #[test]
    fn lower_dram_footprint_lowers_carbon() {
        // The Fig 13 "+SSDs saves 22 GB DRAM" effect.
        let gpu = find("RTX3090").unwrap();
        let mut hi = profile_1h();
        hi.dram_gib = 60.0;
        let mut lo = profile_1h();
        lo.dram_gib = 38.0;
        let bh = footprint(gpu, &hi, 820.0, false);
        let bl = footprint(gpu, &lo, 820.0, false);
        assert!(bl.total_g() < bh.total_g());
    }
}
