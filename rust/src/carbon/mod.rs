//! Carbon-footprint accounting: the Fig 1 GPU database and the
//! embodied + operational emission model (Formula 1).

pub mod gpu_db;
pub mod model;

pub use gpu_db::{find as find_gpu, GpuSpec, GPUS};
pub use model::{
    footprint, g_per_token, CarbonBreakdown, RunProfile, PAPER_INTENSITY_G_PER_KWH,
};
