//! GPU database backing Figure 1: release year, FP16 throughput, HBM
//! size, TDP, embodied carbon, and per-hour operational carbon at the
//! paper's grid intensity. Values are public-spec numbers (TechPowerUp /
//! vendor datasheets) plus the embodied estimates the paper cites
//! (A100 ≈ 150 kgCO2e, [75]); older dies scaled by area/node per ACT [72].

/// One GPU entry.
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    pub name: &'static str,
    pub year: u32,
    /// Peak FP16 (or FP32 for pre-tensor-core parts) TFLOPs.
    pub tflops: f64,
    /// On-board memory in GiB.
    pub mem_gib: f64,
    /// Memory bandwidth GB/s.
    pub mem_bw_gbps: f64,
    /// Board power (TDP) in watts.
    pub tdp_w: f64,
    /// Embodied manufacturing footprint, kgCO2e.
    pub embodied_kg: f64,
    /// Class: consumer ("old-fashioned") vs datacenter ("top-tier").
    pub top_tier: bool,
}

/// The Fig 1 population, K40 (2013) through H100 (2022).
pub const GPUS: &[GpuSpec] = &[
    GpuSpec { name: "K40",      year: 2013, tflops: 4.29,  mem_gib: 12.0, mem_bw_gbps: 288.0,  tdp_w: 235.0, embodied_kg: 35.0,  top_tier: true },
    GpuSpec { name: "M40",      year: 2015, tflops: 6.84,  mem_gib: 24.0, mem_bw_gbps: 288.0,  tdp_w: 250.0, embodied_kg: 45.0,  top_tier: true },
    GpuSpec { name: "P100",     year: 2016, tflops: 19.05, mem_gib: 16.0, mem_bw_gbps: 732.0,  tdp_w: 300.0, embodied_kg: 70.0,  top_tier: true },
    GpuSpec { name: "V100",     year: 2017, tflops: 31.4,  mem_gib: 32.0, mem_bw_gbps: 900.0,  tdp_w: 300.0, embodied_kg: 95.0,  top_tier: true },
    GpuSpec { name: "RTX3060",  year: 2021, tflops: 12.74, mem_gib: 12.0, mem_bw_gbps: 360.0,  tdp_w: 170.0, embodied_kg: 55.0,  top_tier: false },
    GpuSpec { name: "RTX3090",  year: 2020, tflops: 35.58, mem_gib: 24.0, mem_bw_gbps: 936.0,  tdp_w: 350.0, embodied_kg: 85.0,  top_tier: false },
    GpuSpec { name: "RTX4090",  year: 2022, tflops: 82.58, mem_gib: 24.0, mem_bw_gbps: 1008.0, tdp_w: 450.0, embodied_kg: 110.0, top_tier: false },
    GpuSpec { name: "A100",     year: 2020, tflops: 77.97, mem_gib: 80.0, mem_bw_gbps: 2039.0, tdp_w: 400.0, embodied_kg: 150.0, top_tier: true },
    GpuSpec { name: "H100",     year: 2022, tflops: 133.8, mem_gib: 80.0, mem_bw_gbps: 3350.0, tdp_w: 700.0, embodied_kg: 255.0, top_tier: true },
];

pub fn find(name: &str) -> Option<&'static GpuSpec> {
    GPUS.iter().find(|g| g.name.eq_ignore_ascii_case(name))
}

impl GpuSpec {
    /// Operational carbon per hour at full TDP, grams CO2e, at the given
    /// grid intensity (gCO2/kWh).
    pub fn oce_per_hour_g(&self, intensity_g_per_kwh: f64) -> f64 {
        self.tdp_w / 1000.0 * intensity_g_per_kwh
    }

    /// FLOPs per watt — the sustainability-efficiency axis of Fig 1.
    pub fn tflops_per_watt(&self) -> f64 {
        self.tflops / self.tdp_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name_case_insensitive() {
        assert!(find("rtx3090").is_some());
        assert!(find("H100").is_some());
        assert!(find("TPUv9").is_none());
    }

    #[test]
    fn fig1_trends_hold() {
        // Over the decade: FLOPs growth outpaces memory growth (the
        // paper's headline observation on Fig 1).
        let k40 = find("K40").unwrap();
        let h100 = find("H100").unwrap();
        let flops_growth = h100.tflops / k40.tflops;
        let mem_growth = h100.mem_gib / k40.mem_gib;
        assert!(
            flops_growth > 3.0 * mem_growth,
            "flops x{flops_growth:.1} vs mem x{mem_growth:.1}"
        );
    }

    #[test]
    fn m40_vs_h100_carbon_claim() {
        // Paper abstract: M40 has ~1/3 the (operational) carbon of H100.
        let m40 = find("M40").unwrap();
        let h100 = find("H100").unwrap();
        let ratio = m40.oce_per_hour_g(820.0) / h100.oce_per_hour_g(820.0);
        assert!(
            (0.25..0.45).contains(&ratio),
            "M40/H100 OCE ratio {ratio:.2} outside paper band"
        );
    }

    #[test]
    fn embodied_monotone_with_recency_within_tier() {
        let tiers: Vec<&GpuSpec> = GPUS.iter().filter(|g| g.top_tier).collect();
        for w in tiers.windows(2) {
            if w[1].year >= w[0].year {
                assert!(w[1].embodied_kg >= w[0].embodied_kg);
            }
        }
    }

    #[test]
    fn oce_formula() {
        let g = find("RTX3090").unwrap();
        // 350 W for 1 h at 820 g/kWh = 287 g.
        assert!((g.oce_per_hour_g(820.0) - 287.0).abs() < 1e-9);
    }
}
