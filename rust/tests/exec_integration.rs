//! Integration tests over the executed engine (full rust→PJRT stack).
//! These need `make artifacts`; every test no-ops politely otherwise.

use m2cache::coordinator::{
    tokenize, EngineConfig, ExecEngine, Outcome, PolicyKind, Request, SchedConfig, Scheduler,
};
use m2cache::precision::plan::PrecisionRatios;
use std::path::{Path, PathBuf};

fn artifacts() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("layer_step.hlo.txt").exists().then_some(p)
}

macro_rules! need_artifacts {
    () => {
        match artifacts() {
            Some(p) => p,
            None => {
                eprintln!("skipping: run `make artifacts`");
                return;
            }
        }
    };
}

#[test]
fn generation_is_deterministic() {
    let art = need_artifacts!();
    let prompt = tokenize("the quick brown fox ");
    let mut e1 = ExecEngine::new(&art, EngineConfig::full()).unwrap();
    let mut e2 = ExecEngine::new(&art, EngineConfig::full()).unwrap();
    let a = e1.generate(&prompt, 24).unwrap();
    let b = e2.generate(&prompt, 24).unwrap();
    assert_eq!(a, b, "same config must generate identical tokens");
}

#[test]
fn caches_are_numerically_transparent() {
    // The multi-level cache must never change the math: identical
    // outputs with the HBM cache on/off and the SSD tier on/off.
    let art = need_artifacts!();
    let prompt = tokenize("a journey of a thousand ");
    let mut configs = vec![EngineConfig::full()];
    configs.push(EngineConfig::ablation_with_cache());
    configs.push(EngineConfig::ablation_mp_only());
    let outs: Vec<Vec<u32>> = configs
        .into_iter()
        .map(|cfg| {
            ExecEngine::new(&art, cfg)
                .unwrap()
                .generate(&prompt, 16)
                .unwrap()
        })
        .collect();
    assert_eq!(outs[0], outs[1], "ssd tier changed outputs");
    assert_eq!(outs[1], outs[2], "hbm cache changed outputs");
}

#[test]
fn trained_model_continues_corpus_sentences() {
    // The tiny model was trained on the shared corpus; greedy decode
    // from a training prefix must reproduce recognizable content.
    let art = need_artifacts!();
    let mut e = ExecEngine::new(&art, EngineConfig::full()).unwrap();
    // Dense (all-fp16) for maximum fidelity.
    e.set_ratios(PrecisionRatios::new(1.0, 0.0, 0.0));
    let out = e.generate(&tokenize("the quick brown fox "), 24).unwrap();
    let text = m2cache::coordinator::detokenize(&out);
    assert!(
        text.contains("jump") || text.contains("over") || text.contains("lazy"),
        "continuation lost the corpus: {text:?}"
    );
}

#[test]
fn mixed_precision_stays_close_to_dense() {
    // Table-14 invariant: the paper mix degrades accuracy only
    // marginally vs dense on in-domain text.
    let art = need_artifacts!();
    let windows = m2cache::experiments::accuracy::eval_windows(2, 48, 5);
    let mut e = ExecEngine::new(&art, EngineConfig::full()).unwrap();
    e.set_ratios(PrecisionRatios::new(1.0, 0.0, 0.0));
    let mut dense_acc = 0.0;
    for w in &windows {
        dense_acc += e.score_sequence(w).unwrap().1;
    }
    e.set_ratios(PrecisionRatios::new(0.10, 0.10, 0.20));
    let mut m2_acc = 0.0;
    for w in &windows {
        m2_acc += e.score_sequence(w).unwrap().1;
    }
    let n = windows.len() as f64;
    let (dense_acc, m2_acc) = (dense_acc / n, m2_acc / n);
    assert!(dense_acc > 0.5, "dense model should predict well: {dense_acc}");
    assert!(
        m2_acc > dense_acc - 0.15,
        "M2Cache degraded too much: {m2_acc} vs {dense_acc}"
    );
}

#[test]
fn sequence_overflow_is_an_error_not_a_crash() {
    let art = need_artifacts!();
    let mut e = ExecEngine::new(&art, EngineConfig::full()).unwrap();
    let max = e.max_seq();
    for i in 0..max {
        e.feed((i % 200) as u32).unwrap();
    }
    assert!(e.feed(0).is_err(), "feeding past max_seq must error");
    e.reset();
    assert!(e.feed(0).is_ok(), "reset recovers");
}

#[test]
fn out_of_vocab_token_rejected() {
    let art = need_artifacts!();
    let mut e = ExecEngine::new(&art, EngineConfig::full()).unwrap();
    assert!(e.feed(9999).is_err());
}

#[test]
fn policies_do_not_change_outputs() {
    let art = need_artifacts!();
    let prompt = tokenize("large language models ");
    let mut outs = Vec::new();
    for policy in [PolicyKind::Atu, PolicyKind::Lru, PolicyKind::SlidingWindow(3)] {
        let mut cfg = EngineConfig::full();
        cfg.policy = policy;
        let mut e = ExecEngine::new(&art, cfg).unwrap();
        outs.push(e.generate(&prompt, 12).unwrap());
    }
    assert_eq!(outs[0], outs[1], "LRU diverged from ATU");
    assert_eq!(outs[0], outs[2], "sliding window diverged from ATU");
}

#[test]
fn oversubscribed_exec_serving_resumes_byte_identically() {
    // The tentpole's executed-path acceptance: 2 sessions over 1
    // physical KV slot. The High latecomer preempts the Batch resident
    // (its KV spills through the tiered store and comes back), and both
    // outputs are byte-identical to uncontended runs.
    let art = need_artifacts!();
    use m2cache::coordinator::Priority;
    let reqs = [("the quick brown fox ", 10usize), ("pack my box with ", 6usize)];
    let mut reference = Vec::new();
    for (p, n) in &reqs {
        let mut e = ExecEngine::new(&art, EngineConfig::full()).unwrap();
        reference.push(e.generate(&tokenize(p), *n).unwrap());
    }
    let mut cfg = EngineConfig::full();
    cfg.max_sessions = 2;
    cfg.kv_slots = Some(1);
    let eng = ExecEngine::new(&art, cfg).unwrap();
    let mut sched = Scheduler::with_config(eng, 2, SchedConfig::default());
    sched.submit(
        Request::new(1, tokenize(reqs[0].0), reqs[0].1).with_class(Priority::Batch, None),
    );
    for _ in 0..3 {
        sched.tick(); // request 1 reaches decode, KV populated
    }
    sched.submit(
        Request::new(2, tokenize(reqs[1].0), reqs[1].1)
            .with_class(Priority::High, Some(600_000)),
    );
    let outs = sched.run_until_idle();
    assert_eq!(sched.preemptions, 1, "High must preempt the Batch resident");
    assert_eq!(sched.resumes, 1);
    assert_eq!(sched.rejected, 0);
    let mut got: Vec<(u64, Vec<u32>)> = outs
        .into_iter()
        .map(|o| match o {
            Outcome::Done(c) => (c.response.id, c.response.tokens),
            Outcome::Failed { id, error } => panic!("req {id}: {error}"),
        })
        .collect();
    got.sort_by_key(|(id, _)| *id);
    assert_eq!(got.len(), 2);
    assert_eq!(got[0].1, reference[0], "preempted-then-resumed bytes diverged");
    assert_eq!(got[1].1, reference[1]);
    let tel = &sched.engine().tel;
    assert!(tel.kv_spill.spills() >= 1, "{:?}", tel.kv_spill);
    assert_eq!(tel.kv_spill.spills(), tel.kv_spill.restores());
    assert_eq!(tel.kv_spill.spill_bytes(), tel.kv_spill.restore_bytes());
}

#[test]
fn corrupt_spill_recovers_by_recompute_on_the_executed_engine() {
    // The degradation ladder on the real rust→PJRT stack: every spill
    // record is silently bit-flipped in flight, so the preempted
    // session's restore fails its CRC check and the scheduler
    // recomputes it from the prompt. No request fails, and the
    // recomputed bytes equal the uncontended reference.
    let art = need_artifacts!();
    use m2cache::coordinator::Priority;
    let reqs = [("the quick brown fox ", 10usize), ("pack my box with ", 6usize)];
    let mut reference = Vec::new();
    for (p, n) in &reqs {
        let mut e = ExecEngine::new(&art, EngineConfig::full()).unwrap();
        reference.push(e.generate(&tokenize(p), *n).unwrap());
    }
    let mut cfg = EngineConfig::full();
    cfg.max_sessions = 2;
    cfg.kv_slots = Some(1);
    cfg.faults.bit_flip = 1.0; // corrupt every spill record in flight
    let eng = ExecEngine::new(&art, cfg).unwrap();
    let mut sched = Scheduler::with_config(eng, 2, SchedConfig::default());
    sched.submit(
        Request::new(1, tokenize(reqs[0].0), reqs[0].1).with_class(Priority::Batch, None),
    );
    for _ in 0..3 {
        sched.tick(); // request 1 reaches decode, KV populated
    }
    sched.submit(
        Request::new(2, tokenize(reqs[1].0), reqs[1].1)
            .with_class(Priority::High, Some(600_000)),
    );
    let outs = sched.run_until_idle();
    assert_eq!(sched.preemptions, 1, "High must preempt the Batch resident");
    assert_eq!(sched.resumes, 0, "a corrupt record must never restore");
    assert_eq!(sched.recoveries, 1, "the preempted session recomputes");
    let mut got: Vec<(u64, Vec<u32>)> = outs
        .into_iter()
        .map(|o| match o {
            Outcome::Done(c) => (c.response.id, c.response.tokens),
            Outcome::Failed { id, error } => panic!("req {id}: {error}"),
        })
        .collect();
    got.sort_by_key(|(id, _)| *id);
    assert_eq!(got.len(), 2);
    assert_eq!(got[0].1, reference[0], "recomputed bytes diverged");
    assert_eq!(got[1].1, reference[1]);
    let tel = &sched.engine().tel;
    assert!(tel.faults.injected_bit_flips >= 1, "{:?}", tel.faults);
    assert!(tel.faults.crc_failures >= 1, "{:?}", tel.faults);
}

#[test]
fn batched_serving_matches_sequential() {
    // The tentpole's executed-path acceptance: serving the same
    // requests through batched turn-set assembly (shared per-layer
    // pass, union-plan reconciliation, one weight upload per layer per
    // turn) must produce byte-identical tokens to each request decoded
    // alone on a fresh engine. The masked per-lane path runs the same
    // HLO with the same operands as sequential serving, so equality is
    // exact, not approximate.
    let art = need_artifacts!();
    let prompts = [
        "the quick brown fox ",
        "a journey of a thousand ",
        "large language models ",
    ];
    let n_gen = 12;
    // Reference: each request alone, warm-start engine per request.
    let mut reference = Vec::new();
    for p in &prompts {
        let mut e = ExecEngine::new(&art, EngineConfig::full()).unwrap();
        reference.push(e.generate(&tokenize(p), n_gen).unwrap());
    }
    // Batched serving: all three co-resident over one shared engine.
    let mut cfg = EngineConfig::full();
    cfg.max_sessions = 3;
    cfg.batch = true;
    let engine = ExecEngine::new(&art, cfg).unwrap();
    let sched_cfg = SchedConfig {
        batch: true,
        ..SchedConfig::default()
    };
    let mut sched = Scheduler::with_config(engine, 3, sched_cfg);
    for (i, p) in prompts.iter().enumerate() {
        sched.submit(Request::new(i as u64 + 1, tokenize(p), n_gen));
    }
    let mut got = vec![Vec::new(); prompts.len()];
    for o in sched.run_until_idle() {
        match o {
            Outcome::Done(c) => got[c.response.id as usize - 1] = c.response.tokens,
            Outcome::Failed { id, error } => panic!("request {id} failed: {error}"),
        }
    }
    assert_eq!(got, reference, "batched serving changed generated bytes");
    let eng = sched.into_engine();
    assert!(eng.tel.batch_turns > 0, "no shared passes ran");
    assert!(
        eng.tel.batch_occupancy() > 1.5,
        "occupancy {} too low for 3 co-resident sessions",
        eng.tel.batch_occupancy()
    );
    assert!(eng.tel.union_plan_hits > 0, "unions never hit the cache");
}

#[test]
fn batched_kernel_path_matches_when_artifact_present() {
    // Optional stacked-HLO dispatch (--batch-kernel): exercised only
    // when the artifact set ships `layer_step_batch`. The kernel
    // computes each lane with the same per-lane graph the single-token
    // kernel traces (unrolled lanes, shared weights), so greedy tokens
    // must match the masked per-lane path.
    let art = need_artifacts!();
    if !art.join("layer_step_batch.hlo.txt").exists() {
        eprintln!("skipping: artifacts predate layer_step_batch (re-run `make artifacts`)");
        return;
    }
    let prompts = ["the cache keeps the ", "mixed precision trades "];
    let run = |batch_kernel: bool| -> Vec<Vec<u32>> {
        let mut cfg = EngineConfig::full();
        cfg.max_sessions = 2;
        cfg.batch = true;
        cfg.batch_kernel = batch_kernel;
        let engine = ExecEngine::new(&art, cfg).unwrap();
        let sched_cfg = SchedConfig {
            batch: true,
            ..SchedConfig::default()
        };
        let mut sched = Scheduler::with_config(engine, 2, sched_cfg);
        for (i, p) in prompts.iter().enumerate() {
            sched.submit(Request::new(i as u64 + 1, tokenize(p), 10));
        }
        let mut got = vec![Vec::new(); prompts.len()];
        for o in sched.run_until_idle() {
            match o {
                Outcome::Done(c) => got[c.response.id as usize - 1] = c.response.tokens,
                Outcome::Failed { id, error } => panic!("request {id} failed: {error}"),
            }
        }
        got
    };
    assert_eq!(
        run(false),
        run(true),
        "stacked layer_step_batch diverged from the masked per-lane path"
    );
}

#[test]
fn telemetry_accounting_consistent() {
    let art = need_artifacts!();
    let mut e = ExecEngine::new(&art, EngineConfig::full()).unwrap();
    let _ = e.generate(&tokenize("the cache keeps "), 20).unwrap();
    let t = &e.tel;
    assert_eq!(t.tokens_generated, 20);
    assert!(t.ttft_s > 0.0);
    // Every plan entry was either a hit or a load.
    assert!(t.cache_hits + t.cache_misses > 0);
    // Traffic only flows when there are misses.
    assert!(t.traffic.dram_to_hbm > 0);
    assert!(t.hit_ratio() > 0.0 && t.hit_ratio() < 1.0);
}

#[test]
fn fleet_handoff_between_exec_engines_is_byte_identical() {
    // The fleet tentpole's executed-path acceptance: two in-process
    // ExecEngines over the same artifact set, every session forced to
    // migrate mid-decode with its real KV rows travelling as an M2KV
    // handoff record. Greedy decode is deterministic, so the fleet's
    // outputs must match a lone engine decoding each prompt by itself.
    let art = need_artifacts!();
    use m2cache::carbon::find_gpu;
    use m2cache::coordinator::{Fleet, FleetConfig, PhaseCost};
    let reqs = [
        ("the quick brown fox ", 10usize),
        ("pack my box with ", 8usize),
        ("a journey of a thousand ", 6usize),
    ];
    let mut reference = Vec::new();
    for (p, n) in &reqs {
        let mut e = ExecEngine::new(&art, EngineConfig::full()).unwrap();
        reference.push(e.generate(&tokenize(p), *n).unwrap());
    }
    let mk = || {
        let mut cfg = EngineConfig::full();
        cfg.max_sessions = reqs.len();
        cfg.kv_slots = Some(reqs.len());
        ExecEngine::new(&art, cfg).unwrap()
    };
    let mut fleet = Fleet::new(FleetConfig {
        force_handoff: true,
        handoff_after: 1,
        min_remaining: 1,
        ..FleetConfig::default()
    });
    fleet.add_replica(mk(), find_gpu("A100").unwrap(), PhaseCost::uniform(1.0));
    fleet.add_replica(mk(), find_gpu("M40").unwrap(), PhaseCost::uniform(1.0));
    for (i, (p, n)) in reqs.iter().enumerate() {
        fleet.submit_at(0, Request::new(i as u64 + 1, tokenize(p), *n)).unwrap();
    }
    while fleet.step().unwrap() {}
    assert!(fleet.all_done());
    let report = fleet.report();
    // Slots match sessions on both replicas, so the forced migration
    // of every session is structurally guaranteed.
    assert_eq!(report.counters.handoffs, reqs.len() as u64, "{:?}", report.counters);
    assert_eq!(report.counters.handoff_recoveries, 0, "clean handoffs must not recompute");
    let got = fleet.outputs();
    assert_eq!(got.len(), reqs.len());
    for (i, want) in reference.iter().enumerate() {
        assert_eq!(got[i].0, i as u64 + 1);
        assert_eq!(&got[i].1, want, "request {} bytes diverged after handoff", i + 1);
    }
    // The engines' own telemetry saw the migrations too.
    let out0 = fleet.engine(0).tel.counters.get("sessions_handed_off").copied().unwrap_or(0);
    let out1 = fleet.engine(1).tel.counters.get("sessions_handed_off").copied().unwrap_or(0);
    let in0 = fleet.engine(0).tel.counters.get("sessions_handed_in").copied().unwrap_or(0);
    let in1 = fleet.engine(1).tel.counters.get("sessions_handed_in").copied().unwrap_or(0);
    assert_eq!(out0 + out1, reqs.len() as u64);
    assert_eq!(in0 + in1, reqs.len() as u64);
}
