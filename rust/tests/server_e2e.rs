//! End-to-end TCP serving tests: boot the real server (executed engine
//! + PJRT) on an ephemeral port, run concurrent clients, and check the
//! protocol, the multi-session scheduler, and cache transparency under
//! interleaving. Needs `make artifacts`.

use m2cache::coordinator::{EngineConfig, ExecEngine};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::mpsc;

fn have_artifacts() -> bool {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/layer_step.hlo.txt")
        .exists()
}

fn request(addr: std::net::SocketAddr, line: &str) -> String {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(conn);
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    reply.trim().to_string()
}

/// Parsed `OK <id> <queue_ms> <ttft_ms> <total_ms> <text...>` reply.
struct OkReply {
    queue_ms: f64,
    ttft_ms: f64,
    total_ms: f64,
    text: String,
}

fn parse_ok(reply: &str) -> OkReply {
    assert!(reply.starts_with("OK "), "{reply}");
    let mut parts = reply.splitn(6, ' ');
    parts.next(); // OK
    let _id: u64 = parts.next().unwrap().parse().unwrap();
    let queue_ms: f64 = parts.next().unwrap().parse().unwrap();
    let ttft_ms: f64 = parts.next().unwrap().parse().unwrap();
    let total_ms: f64 = parts.next().unwrap().parse().unwrap();
    OkReply {
        queue_ms,
        ttft_ms,
        total_ms,
        text: parts.next().unwrap_or("").to_string(),
    }
}

/// Boot a server over a fresh engine with `sessions` concurrent slots,
/// answering exactly `max` requests; returns (address, join handle).
fn spawn_server(
    sessions: usize,
    max: u64,
) -> (
    std::net::SocketAddr,
    std::thread::JoinHandle<m2cache::telemetry::Telemetry>,
) {
    let (addr_tx, addr_rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        let mut cfg = EngineConfig::full();
        cfg.max_sessions = sessions;
        let engine = ExecEngine::new(
            &Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            cfg,
        )
        .unwrap();
        let engine = m2cache::coordinator::server::serve(
            engine,
            "127.0.0.1:0",
            Some(max),
            move |a| {
                let _ = addr_tx.send(a);
            },
        )
        .unwrap();
        engine.tel
    });
    (addr_rx.recv().unwrap(), handle)
}

#[test]
fn serves_concurrent_clients_and_stats() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let n_gen = 4u64; // GEN requests answered before shutdown
    let (addr, server) = spawn_server(2, n_gen);

    // STATS must answer without consuming a GEN slot.
    let stats = request(addr, "STATS");
    assert!(stats.starts_with('{') && stats.contains("enqueued"), "{stats}");
    assert!(stats.contains("active"), "{stats}");

    // Bad requests → ERR.
    assert!(request(addr, "NONSENSE").starts_with("ERR"));
    assert!(request(addr, "GEN notanumber hi").starts_with("ERR"));

    // Concurrent GENs.
    let mut clients = Vec::new();
    for i in 0..n_gen {
        clients.push(std::thread::spawn(move || {
            request(addr, &format!("GEN 8 the quick brown fox {i}"))
        }));
    }
    for c in clients {
        let reply = c.join().unwrap();
        let ok = parse_ok(&reply);
        assert!(ok.ttft_ms >= ok.queue_ms, "{reply}");
        assert!(ok.total_ms >= ok.ttft_ms, "{reply}");
        assert!(!ok.text.is_empty(), "{reply}");
    }
    let tel = server.join().unwrap();
    // Aggregate accounting: 4 sessions x 8 tokens each.
    assert_eq!(tel.tokens_generated, n_gen * 8);
    assert_eq!(tel.counters.get("sessions_closed"), Some(&n_gen));
    assert!(tel.kv_pool_bytes > 0);
}

#[test]
fn interleaved_sessions_match_sequential_outputs() {
    // Acceptance: K=4 concurrent GENs through the interleaving
    // scheduler produce byte-identical outputs to the same prompts
    // served strictly sequentially — the shared HBM/DRAM caches are
    // numerically transparent across interleaving.
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let prompts = [
        "the quick brown fox ",
        "a journey of a thousand ",
        "large language models ",
        "the cache keeps the ",
    ];
    let run = |sessions: usize| -> (HashMap<String, String>, m2cache::telemetry::Telemetry) {
        let (addr, server) = spawn_server(sessions, prompts.len() as u64);
        let mut clients = Vec::new();
        for p in prompts {
            clients.push(std::thread::spawn(move || {
                (p.to_string(), request(addr, &format!("GEN 12 {p}")))
            }));
        }
        let mut out = HashMap::new();
        for c in clients {
            let (prompt, reply) = c.join().unwrap();
            let ok = parse_ok(&reply);
            assert!(ok.queue_ms >= 0.0 && ok.total_ms >= ok.ttft_ms, "{reply}");
            out.insert(prompt, ok.text);
        }
        (out, server.join().unwrap())
    };
    let (sequential, tel_seq) = run(1);
    let (interleaved, tel_int) = run(4);
    assert_eq!(
        sequential, interleaved,
        "interleaving changed generated bytes"
    );
    // Telemetry: aggregate tokens equal the per-session sum both ways.
    let expected = (prompts.len() * 12) as u64;
    assert_eq!(tel_seq.tokens_generated, expected);
    assert_eq!(tel_int.tokens_generated, expected);
    assert!(tel_int.peak_active_sessions > 1, "scheduler never interleaved");
    assert_eq!(tel_seq.peak_active_sessions, 1);
}
