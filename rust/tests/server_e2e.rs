//! End-to-end TCP serving test: boots the real server (executed engine
//! + PJRT) on an ephemeral port, runs concurrent clients, and checks
//! the protocol + results. Needs `make artifacts`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::mpsc;

fn have_artifacts() -> bool {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/layer_step.hlo.txt")
        .exists()
}

fn request(addr: std::net::SocketAddr, line: &str) -> String {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(conn);
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    reply.trim().to_string()
}

#[test]
fn serves_concurrent_clients_and_stats() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (addr_tx, addr_rx) = mpsc::channel();
    let n_gen = 4usize; // GEN requests answered before shutdown
    let server = std::thread::spawn(move || {
        let engine = m2cache::coordinator::ExecEngine::new(
            &Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            m2cache::coordinator::EngineConfig::full(),
        )
        .unwrap();
        m2cache::coordinator::server::serve(
            engine,
            "127.0.0.1:0",
            Some(n_gen as u64),
            move |a| {
                let _ = addr_tx.send(a);
            },
        )
        .unwrap();
    });
    let addr = addr_rx.recv().unwrap();

    // STATS must answer without consuming a GEN slot.
    let stats = request(addr, "STATS");
    assert!(stats.starts_with('{') && stats.contains("enqueued"), "{stats}");

    // Bad request → ERR.
    assert!(request(addr, "NONSENSE").starts_with("ERR"));
    assert!(request(addr, "GEN notanumber hi").starts_with("ERR"));

    // Concurrent GENs.
    let mut clients = Vec::new();
    for i in 0..n_gen {
        clients.push(std::thread::spawn(move || {
            request(addr, &format!("GEN 8 the quick brown fox {i}"))
        }));
    }
    let mut oks = 0;
    for c in clients {
        let reply = c.join().unwrap();
        assert!(reply.starts_with("OK "), "{reply}");
        // OK <id> <queue_ms> <total_ms> <text>
        let mut parts = reply.split_whitespace();
        parts.next();
        let _id: u64 = parts.next().unwrap().parse().unwrap();
        let queue_ms: f64 = parts.next().unwrap().parse().unwrap();
        let total_ms: f64 = parts.next().unwrap().parse().unwrap();
        assert!(total_ms >= queue_ms);
        oks += 1;
    }
    assert_eq!(oks, n_gen);
    server.join().unwrap();
}
