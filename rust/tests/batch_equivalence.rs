//! Batched-vs-sequential byte-equality tier — runs WITHOUT artifacts.
//!
//! The tentpole contract: [`SchedConfig::batch`] changes engine
//! *granularity* (one shared pass per turn instead of one pass per
//! session), never bytes. This tier proves it property-style over
//! random session mixes — mixed prefill/decode lengths, mixed priority
//! classes, more sessions than slots — against the three engine shapes
//! the trait admits:
//!
//! 1. a stub on the **default** `forward_batch` (per-session loop), the
//!    shape every pre-existing engine gets for free;
//! 2. a stub that **overrides** `forward_batch` and services lanes in
//!    reverse order, proving the scheduler depends only on the
//!    slot-`i`-answers-`steps[i]` contract, not on call order;
//! 3. the executed PJRT engine — covered artifact-gated in
//!    `exec_integration.rs` (`batched_serving_matches_sequential`).
//!
//! The reference is each request decoded alone to completion on a
//! fresh stub — the strongest form of "interleaving changed nothing".

use anyhow::Result;
use m2cache::coordinator::{
    DecodeSession, Outcome, Priority, Request, SchedConfig, Scheduler, SessionEngine,
};
use m2cache::util::check::Check;
use m2cache::util::rng::Rng;
use std::collections::HashMap;

const VOCAB: usize = 89;

/// Deterministic stub: logits are a pure function of (token, pos), so
/// any correct schedule reproduces identical per-request bytes. Slots
/// come from a real free list so aliasing bugs would surface.
struct Stub {
    slots: usize,
    free: Vec<usize>,
    /// Lane counts of every forward_batch call (occupancy evidence).
    batch_sizes: Vec<usize>,
    /// Service lanes in reverse order when set (override shape #2).
    reverse: bool,
}

impl Stub {
    fn new(slots: usize, reverse: bool) -> Stub {
        Stub {
            slots,
            free: (0..slots).rev().collect(),
            batch_sizes: Vec::new(),
            reverse,
        }
    }

    fn logits(token: u32, pos: usize) -> Vec<f32> {
        let mut l = vec![0.0f32; VOCAB];
        l[((token as usize).wrapping_mul(13) + pos * 5 + 2) % VOCAB] = 1.0;
        l
    }
}

impl SessionEngine for Stub {
    fn capacity(&self) -> usize {
        self.slots
    }

    fn open(&mut self, req: Request) -> Result<DecodeSession> {
        anyhow::ensure!(!req.prompt.is_empty(), "empty prompt");
        let slot = self
            .free
            .pop()
            .ok_or_else(|| anyhow::anyhow!("kv pool exhausted"))?;
        Ok(DecodeSession::new(req, slot))
    }

    fn forward(&mut self, s: &DecodeSession, token: u32) -> Result<Vec<f32>> {
        assert!(!self.free.contains(&s.slot()), "stepped on a freed slot");
        Ok(Stub::logits(token, s.pos()))
    }

    fn forward_batch(&mut self, steps: &[(&DecodeSession, u32)]) -> Vec<Result<Vec<f32>>> {
        self.batch_sizes.push(steps.len());
        if !self.reverse {
            return steps.iter().map(|(s, t)| self.forward(s, *t)).collect();
        }
        // Service lanes back-to-front, answer front-to-back: result
        // slot i must still belong to steps[i].
        let mut out: Vec<Result<Vec<f32>>> = Vec::with_capacity(steps.len());
        for (s, t) in steps.iter().rev() {
            out.push(self.forward(s, *t));
        }
        out.reverse();
        out
    }

    fn close(&mut self, s: &mut DecodeSession) {
        assert!(!self.free.contains(&s.slot()), "double release");
        self.free.push(s.slot());
    }
}

/// Random request mix: prompts 1..12 tokens, 0..8 decode tokens, all
/// three priority classes, some deadlines.
fn random_requests(rng: &mut Rng, n: usize) -> Vec<Request> {
    (1..=n)
        .map(|id| {
            let plen = rng.range(1, 12);
            let prompt: Vec<u32> = (0..plen).map(|_| rng.below(VOCAB as u64) as u32).collect();
            let max_new = rng.range(0, 8);
            let (priority, deadline) = match rng.range(0, 4) {
                0 => (Priority::High, Some(rng.range(50, 500) as u64)),
                1 => (Priority::Batch, None),
                _ => (Priority::Normal, None),
            };
            Request::new(id as u64, prompt, max_new).with_class(priority, deadline)
        })
        .collect()
}

/// Every request decoded alone to completion — the bytes nothing may
/// change.
fn sequential_reference(requests: &[Request]) -> HashMap<u64, Vec<u32>> {
    let mut eng = Stub::new(1, false);
    let mut out = HashMap::new();
    for r in requests {
        let mut s = eng.open(r.clone()).unwrap();
        while !s.is_done() {
            s.step(&mut eng).unwrap();
        }
        eng.close(&mut s);
        out.insert(r.id, s.generated);
    }
    out
}

fn run_scheduler(
    requests: &[Request],
    slots: usize,
    batch: bool,
    reverse: bool,
) -> (HashMap<u64, Vec<u32>>, Stub) {
    let cfg = SchedConfig {
        batch,
        ..SchedConfig::default()
    };
    let mut sched = Scheduler::with_config(Stub::new(slots, reverse), slots, cfg);
    for r in requests {
        sched.submit(r.clone());
    }
    let mut out = HashMap::new();
    for o in sched.run_until_idle() {
        match o {
            Outcome::Done(c) => {
                out.insert(c.response.id, c.response.tokens);
            }
            Outcome::Failed { id, error } => panic!("request {id} failed: {error}"),
        }
    }
    (out, sched.into_engine())
}

#[test]
fn batched_outputs_are_byte_identical_across_random_mixes() {
    Check::new(32, 0xBA7C).run("batched == sequential", |rng| {
        let n = rng.range(2, 10);
        let slots = rng.range(1, 5);
        let requests = random_requests(rng, n);
        let reference = sequential_reference(&requests);
        for (name, batch, reverse) in [
            ("single-turn", false, false),
            ("batched/default", true, false),
            ("batched/override", true, true),
        ] {
            let (got, _) = run_scheduler(&requests, slots, batch, reverse);
            if got != reference {
                return Err(format!("{name}: scheduler changed generated bytes"));
            }
        }
        Ok(())
    });
}

#[test]
fn batched_mode_actually_batches() {
    // With 4 equal co-resident decode sessions, every shared pass must
    // carry all 4 lanes — occupancy is the whole point.
    let requests: Vec<Request> = (1..=4)
        .map(|id| Request::new(id, vec![5, 6], 6))
        .collect();
    let (out, eng) = run_scheduler(&requests, 4, true, false);
    assert_eq!(out.len(), 4);
    assert!(
        !eng.batch_sizes.is_empty(),
        "batched scheduler never called forward_batch with >= 2 lanes"
    );
    assert!(
        eng.batch_sizes.iter().any(|&b| b == 4),
        "no full-occupancy pass in {:?}",
        eng.batch_sizes
    );
    // Total forwards conserved: 4 sessions x (2 prompt + 5 decode).
    let batched_tokens: usize = eng.batch_sizes.iter().sum();
    assert_eq!(batched_tokens, 4 * 7);
}

#[test]
fn batched_mode_interleaves_overcommitted_backlog() {
    // More requests than slots: the batch is capped at the slot count,
    // retired sessions backfill, and everything still matches the
    // sequential reference.
    let mut rng = Rng::new(0x5EED);
    let requests = random_requests(&mut rng, 12);
    let reference = sequential_reference(&requests);
    let (got, eng) = run_scheduler(&requests, 3, true, false);
    assert_eq!(got, reference);
    assert!(eng.batch_sizes.iter().all(|&b| b <= 3), "{:?}", eng.batch_sizes);
}
