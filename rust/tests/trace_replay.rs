//! Deterministic trace-replay tier — runs WITHOUT `make artifacts`.
//!
//! Seeded synthetic arrival traces (`coordinator::workload`: steady,
//! bursty, adversarial long-prompt mixes) replay against a stub engine
//! on a **virtual clock** (1 ms per engine forward), pinning the
//! priority/deadline scheduling contract end to end:
//!
//! - byte-identical outputs vs sequential execution, for every mix and
//!   both policy modes;
//! - EDF ordering within a priority class on every non-guard turn;
//! - no starvation: any in-flight session gets a turn within
//!   `starvation_guard * slots` turns, even under a saturating
//!   higher-priority stream;
//! - deadline-miss accounting agrees exactly with the replay's own
//!   bookkeeping, per request and per class;
//! - the acceptance bar: under the adversarial long-prompt trace, p99
//!   TTFT of high-priority short requests is **strictly lower** with
//!   chunked-prefill EDF than with PR 1's round-robin, on the same
//!   trace and the same simulated clock.

use anyhow::Result;
use m2cache::carbon::find_gpu;
use m2cache::coordinator::workload::{
    generate, inject_cancellations, inject_shared_prefix, Mix, TraceEvent, TraceSpec,
};
use m2cache::coordinator::{
    DecodeSession, Fleet, FleetConfig, HandoffRecord, KvStore, KvTicket, Outcome, PhaseCost,
    Priority, Request, SchedConfig, SchedMode, Scheduler, SessionEngine, SessionEvent,
    StubSessionEngine,
};
use m2cache::telemetry::{ClassCounters, N_CLASSES};
use std::collections::{HashMap, HashSet};

const VOCAB: usize = 97;

/// Deterministic stub engine: next token is a pure function of the fed
/// token and the session position, so any correct scheduler reproduces
/// the same per-request bytes regardless of interleaving.
/// `StubEngine::spilling` builds one that can park sessions, enabling
/// oversubscription + preemption (positional KV: parking is pure slot
/// bookkeeping).
struct StubEngine {
    slots: usize,
    free: Vec<usize>,
    forwards: u64,
    can_spill: bool,
    next_ticket: u64,
    parked: HashSet<u64>,
    /// Overlapped-restore bookkeeping: tickets the scheduler hinted
    /// via `begin_restore`, hint count, and restores that consumed a
    /// prefetch — the stub analogue of the engine's pipelined KV path.
    prefetched: HashSet<u64>,
    restore_hints: u64,
    overlap_hits: u64,
}

impl StubEngine {
    fn new(slots: usize) -> StubEngine {
        StubEngine {
            slots,
            free: (0..slots).rev().collect(),
            forwards: 0,
            can_spill: false,
            next_ticket: 0,
            parked: HashSet::new(),
            prefetched: HashSet::new(),
            restore_hints: 0,
            overlap_hits: 0,
        }
    }

    fn spilling(slots: usize) -> StubEngine {
        StubEngine {
            can_spill: true,
            ..StubEngine::new(slots)
        }
    }
}

impl SessionEngine for StubEngine {
    fn capacity(&self) -> usize {
        self.slots
    }

    fn open(&mut self, req: Request) -> Result<DecodeSession> {
        anyhow::ensure!(!req.prompt.is_empty(), "empty prompt");
        let slot = self
            .free
            .pop()
            .ok_or_else(|| anyhow::anyhow!("kv pool exhausted"))?;
        Ok(DecodeSession::new(req, slot))
    }

    fn forward(&mut self, s: &DecodeSession, token: u32) -> Result<Vec<f32>> {
        self.forwards += 1;
        assert!(!self.free.contains(&s.slot()), "stepped on a freed slot");
        let mut logits = vec![0.0f32; VOCAB];
        logits[((token as usize).wrapping_mul(31) + s.pos() * 7 + 1) % VOCAB] = 1.0;
        Ok(logits)
    }

    fn close(&mut self, s: &mut DecodeSession) {
        assert!(!self.free.contains(&s.slot()), "double release");
        self.free.push(s.slot());
    }

    fn supports_spill(&self) -> bool {
        self.can_spill
    }

    fn spill(&mut self, s: &DecodeSession) -> Result<KvTicket> {
        anyhow::ensure!(self.can_spill, "engine does not support KV spill");
        assert!(!self.free.contains(&s.slot()), "spilling a freed slot");
        self.free.push(s.slot());
        self.next_ticket += 1;
        self.parked.insert(self.next_ticket);
        Ok(KvTicket::new(self.next_ticket))
    }

    fn restore(&mut self, s: &mut DecodeSession, ticket: KvTicket) -> Result<()> {
        anyhow::ensure!(self.parked.contains(&ticket.id()), "unknown ticket");
        let slot = self
            .free
            .pop()
            .ok_or_else(|| anyhow::anyhow!("no free slot to restore into"))?;
        self.parked.remove(&ticket.id());
        if self.prefetched.remove(&ticket.id()) {
            self.overlap_hits += 1;
        }
        s.rebind_slot(slot);
        Ok(())
    }

    fn discard(&mut self, _s: &mut DecodeSession, ticket: KvTicket) {
        self.parked.remove(&ticket.id());
        self.prefetched.remove(&ticket.id());
    }

    fn begin_restore(&mut self, ticket: KvTicket) {
        // The scheduler's contract: hints name currently parked
        // sessions only (a hint for a freed ticket would prefetch a
        // record another spill may have recycled).
        assert!(
            self.parked.contains(&ticket.id()),
            "overlap hint for ticket {} which is not parked",
            ticket.id()
        );
        self.restore_hints += 1;
        self.prefetched.insert(ticket.id());
    }
}

/// Everything one replay observed, keyed by request id.
struct Replay {
    tokens: HashMap<u64, Vec<u32>>,
    submit_ms: HashMap<u64, u64>,
    /// End-of-turn virtual time of each request's first token.
    ttft_ms: HashMap<u64, u64>,
    finish_ms: HashMap<u64, u64>,
    missed: HashMap<u64, bool>,
    classes: [ClassCounters; N_CLASSES],
    turns: u64,
    guard_turns: u64,
}

/// Drive a trace through the scheduler on a virtual clock: each engine
/// forward costs 1 ms, arrivals land at their trace times. Asserts the
/// EDF and starvation contracts inline while replaying.
fn replay(events: &[TraceEvent], cfg: SchedConfig, slots: usize) -> Replay {
    let mut sched = Scheduler::with_config(StubEngine::new(slots), slots, cfg);
    let mut out = Replay {
        tokens: HashMap::new(),
        submit_ms: HashMap::new(),
        ttft_ms: HashMap::new(),
        finish_ms: HashMap::new(),
        missed: HashMap::new(),
        classes: [ClassCounters::default(); N_CLASSES],
        turns: 0,
        guard_turns: 0,
    };
    // Any in-flight session must get a turn within this many turns.
    let starvation_bound = match cfg.mode {
        SchedMode::RoundRobin => Some(slots as u64),
        SchedMode::PriorityEdf if cfg.starvation_guard > 0 => {
            Some(slots as u64 * cfg.starvation_guard)
        }
        SchedMode::PriorityEdf => None,
    };
    let mut now: u64 = 0;
    sched.set_virtual_now_ms(now);
    let mut next_ev = 0;
    let mut last_turn: HashMap<u64, u64> = HashMap::new();
    loop {
        while next_ev < events.len() && events[next_ev].at_ms <= now {
            let ev = &events[next_ev];
            sched.submit(ev.to_request());
            out.submit_ms.insert(ev.id, now);
            next_ev += 1;
        }
        if sched.is_idle() {
            if next_ev >= events.len() {
                break;
            }
            // Idle gap: jump to the next arrival.
            now = events[next_ev].at_ms;
            sched.set_virtual_now_ms(now);
            continue;
        }
        // Admit before observing, so the view matches what this tick
        // will choose from (tick's own admission pass is then a no-op).
        for o in sched.admit_pending() {
            panic!("trace request rejected at admission: {o:?}");
        }
        let view = sched.active_view();
        let now_pre = now;
        let r = sched.tick();
        now += r.steps_run as u64;
        sched.set_virtual_now_ms(now);
        if let Some(id) = r.stepped {
            out.turns += 1;
            if r.guard {
                out.guard_turns += 1;
            }
            if let Some(bound) = starvation_bound {
                if let Some(&prev) = last_turn.get(&id) {
                    assert!(
                        out.turns - prev <= bound,
                        "session {id} waited {} turns (> {bound})",
                        out.turns - prev
                    );
                }
                last_turn.insert(id, out.turns);
            }
            if cfg.mode == SchedMode::PriorityEdf && !r.guard {
                // EDF within class: nobody in the view may hold a
                // strictly better (class, deadline) key than the
                // session that got the turn.
                let me = view
                    .iter()
                    .find(|a| a.id == id)
                    .expect("stepped session was in the pre-tick view");
                let mine = (me.priority.index(), me.deadline_ms.unwrap_or(u64::MAX));
                for other in &view {
                    let key = (other.priority.index(), other.deadline_ms.unwrap_or(u64::MAX));
                    assert!(
                        key >= mine,
                        "turn gave {id} {mine:?} while {} held {key:?}",
                        other.id
                    );
                }
            }
        }
        for o in r.outcomes {
            match o {
                Outcome::Done(c) => {
                    let id = c.response.id;
                    if !c.response.tokens.is_empty() {
                        out.ttft_ms.entry(id).or_insert(now);
                    }
                    // The scheduler judged the deadline with the
                    // pre-tick clock; mirror that here and require the
                    // per-completion flag to agree.
                    let expect = events[id as usize - 1]
                        .deadline_ms
                        .is_some_and(|d| now_pre > out.submit_ms[&id] + d);
                    assert_eq!(
                        c.deadline_missed, expect,
                        "request {id} miss flag disagrees with the replay clock"
                    );
                    out.missed.insert(id, c.deadline_missed);
                    out.finish_ms.insert(id, now);
                    out.tokens.insert(id, c.response.tokens);
                }
                Outcome::Failed { id, error } => panic!("request {id} failed: {error}"),
            }
        }
        // First token of a still-running session: visible as generated
        // flipping positive in the post-tick view.
        if let Some(id) = r.stepped {
            if !out.ttft_ms.contains_key(&id) {
                if let Some(a) = sched.active_view().iter().find(|a| a.id == id) {
                    if a.generated > 0 {
                        out.ttft_ms.insert(id, now);
                    }
                }
            }
        }
    }
    out.classes = sched.classes;
    out
}

/// Reference: every request alone, stepped to completion sequentially.
fn sequential_reference(events: &[TraceEvent]) -> HashMap<u64, Vec<u32>> {
    let mut eng = StubEngine::new(1);
    let mut tokens = HashMap::new();
    for ev in events {
        let mut s = eng.open(ev.to_request()).unwrap();
        while !s.is_done() {
            s.step(&mut eng).unwrap();
        }
        eng.close(&mut s);
        tokens.insert(ev.id, s.generated);
    }
    tokens
}

fn spec(mix: Mix, n: usize) -> TraceSpec {
    TraceSpec {
        mix,
        n,
        seed: 0x7ACE,
        vocab: VOCAB as u32,
    }
}

fn edf_cfg() -> SchedConfig {
    SchedConfig::default()
}

fn rr_cfg() -> SchedConfig {
    SchedConfig {
        mode: SchedMode::RoundRobin,
        prefill_chunk: 1,
        starvation_guard: 0,
        ..SchedConfig::default()
    }
}

fn p99(mut xs: Vec<u64>) -> u64 {
    assert!(!xs.is_empty());
    xs.sort_unstable();
    let idx = ((xs.len() as f64) * 0.99).ceil() as usize - 1;
    xs[idx.min(xs.len() - 1)]
}

#[test]
fn outputs_are_byte_identical_to_sequential_for_all_mixes() {
    for mix in [Mix::Steady, Mix::Bursty, Mix::AdversarialLongPrompt] {
        let events = generate(&spec(mix, 40));
        let reference = sequential_reference(&events);
        for (name, cfg) in [("edf", edf_cfg()), ("rr", rr_cfg())] {
            let rep = replay(&events, cfg, 3);
            assert_eq!(
                rep.tokens, reference,
                "{mix:?}/{name}: interleaved replay changed generated bytes"
            );
        }
    }
}

#[test]
fn batched_replay_is_byte_identical_to_sequential() {
    // The PR-3 extension of the equality contract: replaying the same
    // traces with batched turn-set assembly (every live session
    // advances per tick through forward_batch) must reproduce the
    // sequential per-request bytes for every mix. Timing-sensitive
    // assertions (EDF-per-turn, starvation bound) are single-turn
    // notions, so the batched replay is a plain drive-to-idle on the
    // scheduler rather than the instrumented `replay` harness.
    for mix in [Mix::Steady, Mix::Bursty, Mix::AdversarialLongPrompt] {
        let events = generate(&spec(mix, 40));
        let reference = sequential_reference(&events);
        let cfg = SchedConfig {
            batch: true,
            ..SchedConfig::default()
        };
        let mut sched = Scheduler::with_config(StubEngine::new(3), 3, cfg);
        sched.set_virtual_now_ms(0);
        let mut now = 0u64;
        let mut next_ev = 0;
        let mut tokens: HashMap<u64, Vec<u32>> = HashMap::new();
        loop {
            while next_ev < events.len() && events[next_ev].at_ms <= now {
                sched.submit(events[next_ev].to_request());
                next_ev += 1;
            }
            if sched.is_idle() {
                if next_ev >= events.len() {
                    break;
                }
                now = events[next_ev].at_ms;
                sched.set_virtual_now_ms(now);
                continue;
            }
            let r = sched.tick();
            // Virtual clock: a batched turn still costs its forwards
            // (the equality claim is about bytes, not time).
            now += r.steps_run as u64;
            sched.set_virtual_now_ms(now);
            for o in r.outcomes {
                match o {
                    Outcome::Done(c) => {
                        tokens.insert(c.response.id, c.response.tokens);
                    }
                    Outcome::Failed { id, error } => panic!("request {id} failed: {error}"),
                }
            }
        }
        assert_eq!(
            tokens, reference,
            "{mix:?}: batched replay changed generated bytes"
        );
    }
}

#[test]
fn replay_is_deterministic() {
    let events = generate(&spec(Mix::Bursty, 48));
    let a = replay(&events, edf_cfg(), 3);
    let b = replay(&events, edf_cfg(), 3);
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.ttft_ms, b.ttft_ms);
    assert_eq!(a.finish_ms, b.finish_ms);
    assert_eq!(a.turns, b.turns);
    assert_eq!(a.classes, b.classes);
}

#[test]
fn every_request_completes_with_exact_token_budget() {
    for mix in [Mix::Steady, Mix::Bursty, Mix::AdversarialLongPrompt] {
        let events = generate(&spec(mix, 40));
        let rep = replay(&events, edf_cfg(), 2);
        assert_eq!(rep.tokens.len(), events.len(), "{mix:?} lost requests");
        for ev in &events {
            assert_eq!(
                rep.tokens[&ev.id].len(),
                ev.max_new,
                "{mix:?} request {} token budget",
                ev.id
            );
        }
        let done: u64 = rep.classes.iter().map(|c| c.completed).sum();
        assert_eq!(done as usize, events.len());
    }
}

#[test]
fn no_starvation_and_edf_hold_under_adversarial_trace() {
    // The EDF-within-class and starvation-bound assertions run inline
    // in replay(); this pins that the adversarial trace actually
    // exercises them (guard turns fired, both classes completed).
    let events = generate(&spec(Mix::AdversarialLongPrompt, 60));
    let rep = replay(&events, edf_cfg(), 2);
    assert!(rep.guard_turns > 0, "guard never fired under saturation");
    assert!(rep.classes[Priority::High.index()].completed >= 10);
    assert!(rep.classes[Priority::Batch.index()].completed >= 40);
}

#[test]
fn deadline_miss_accounting_matches_replay_bookkeeping() {
    for mix in [Mix::Steady, Mix::AdversarialLongPrompt] {
        let events = generate(&spec(mix, 60));
        let rep = replay(&events, edf_cfg(), 2);
        // Per-request flags were checked inline; the per-class counters
        // must be exactly their sums.
        let mut expect = [0u64; N_CLASSES];
        for ev in &events {
            if rep.missed[&ev.id] {
                expect[ev.priority.index()] += 1;
            }
        }
        for (i, c) in rep.classes.iter().enumerate() {
            assert_eq!(
                c.deadline_missed, expect[i],
                "{mix:?} class {i} miss counter"
            );
        }
    }
}

#[test]
fn cancellation_trace_preserves_surviving_bytes_and_frees_every_slot() {
    // A cancellation-bearing trace on the virtual clock: every 3rd
    // batch-class flood request is abandoned 25 virtual ms after it
    // arrives. The contract: cancels are acknowledged exactly once,
    // every surviving request's bytes equal the sequential reference
    // (cancellation is invisible to survivors), cancelled requests
    // never reach their full budget accidentally, and every KV slot is
    // back in the pool at the end.
    const SLOTS: usize = 3;
    let mut events = generate(&spec(Mix::AdversarialLongPrompt, 60));
    let tagged = inject_cancellations(&mut events, 3, 25);
    assert!(tagged >= 10, "trace too thin: {tagged} cancels");
    let reference = sequential_reference(&events);

    // Two cancel shapes. *Timed* cancels fire 25 virtual ms after
    // arrival — far less than any flood prompt's prefill (≥ 48 forwards
    // at 1 ms each), so they deterministically catch their target
    // backlogged or mid-prefill. *Reactive* cancels model a client
    // hanging up after reading streamed output: the first few tagged
    // requests are cancelled the moment their second token is observed,
    // which is deterministically mid-decode.
    let tagged_ids: Vec<u64> = events
        .iter()
        .filter(|e| e.cancel_after_ms.is_some())
        .map(|e| e.id)
        .collect();
    let reactive: HashSet<u64> = tagged_ids.iter().copied().take(4).collect();
    let mut cancels: Vec<(u64, u64)> = events
        .iter()
        .filter(|e| !reactive.contains(&e.id))
        .filter_map(|e| e.cancel_after_ms.map(|d| (e.at_ms + d, e.id)))
        .collect();
    cancels.sort_unstable();

    let mut sched = Scheduler::with_config(StubEngine::new(SLOTS), SLOTS, edf_cfg());
    sched.set_virtual_now_ms(0);
    let mut now = 0u64;
    let mut next_ev = 0usize;
    let mut next_cancel = 0usize;
    let mut tokens: HashMap<u64, Vec<u32>> = HashMap::new();
    let mut cancelled: HashMap<u64, usize> = HashMap::new();
    loop {
        while next_ev < events.len() && events[next_ev].at_ms <= now {
            sched.submit(events[next_ev].to_request());
            next_ev += 1;
        }
        while next_cancel < cancels.len() && cancels[next_cancel].0 <= now {
            let id = cancels[next_cancel].1;
            next_cancel += 1;
            match sched.cancel(id) {
                Some(SessionEvent::Cancelled { id: cid, tokens }) => {
                    assert_eq!(cid, id);
                    assert!(cancelled.insert(id, tokens).is_none(), "double cancel ack");
                }
                Some(ev) => panic!("cancel returned {ev:?}"),
                // Too late — the request finished before the client
                // gave up. Legal; it must then appear in `tokens`.
                None => {}
            }
        }
        if sched.is_idle() {
            if next_ev >= events.len() && next_cancel >= cancels.len() {
                break;
            }
            let jump_ev = events.get(next_ev).map(|e| e.at_ms).unwrap_or(u64::MAX);
            let jump_c = cancels.get(next_cancel).map(|c| c.0).unwrap_or(u64::MAX);
            now = jump_ev.min(jump_c);
            sched.set_virtual_now_ms(now);
            continue;
        }
        let r = sched.tick();
        now += r.steps_run as u64;
        sched.set_virtual_now_ms(now);
        // A cancelled id must never appear in a later turn.
        if let Some(id) = r.stepped {
            assert!(!cancelled.contains_key(&id), "cancelled {id} got a turn");
        }
        for ev in &r.events {
            if let SessionEvent::Token { id, index: 1, .. } = ev {
                if reactive.contains(id) && !cancelled.contains_key(id) {
                    // The client read two streamed tokens and hung up.
                    match sched.cancel(*id) {
                        Some(SessionEvent::Cancelled { tokens, .. }) => {
                            assert!(tokens >= 2, "mid-decode cancel saw {tokens} tokens");
                            cancelled.insert(*id, tokens);
                        }
                        other => panic!("reactive cancel of {id} returned {other:?}"),
                    }
                }
            }
        }
        for o in r.outcomes {
            match o {
                Outcome::Done(c) => {
                    tokens.insert(c.response.id, c.response.tokens);
                }
                Outcome::Failed { id, error } => panic!("request {id} failed: {error}"),
            }
        }
    }
    // Every request settled exactly one way.
    for ev in &events {
        let done = tokens.contains_key(&ev.id);
        let gone = cancelled.contains_key(&ev.id);
        assert!(done ^ gone, "request {} done={done} cancelled={gone}", ev.id);
    }
    assert!(!cancelled.is_empty(), "no cancel landed in time");
    // Byte-equality for every survivor; partial progress for the gone.
    for (id, toks) in &tokens {
        assert_eq!(toks, &reference[id], "survivor {id} bytes changed");
    }
    for (id, partial) in &cancelled {
        let budget = events[*id as usize - 1].max_new;
        assert!(
            *partial < budget,
            "cancelled {id} generated its whole budget ({partial}/{budget})"
        );
    }
    // At least one cancel landed mid-decode (tokens flowing) — the
    // trace exercises the hard path, not just backlog drops.
    assert!(
        cancelled.values().any(|&t| t > 0),
        "every cancel hit before decode: {cancelled:?}"
    );
    // All KV slots returned; class accounting matches.
    assert_eq!(sched.engine().free.len(), SLOTS, "leaked KV slots");
    assert_eq!(sched.cancelled as usize, cancelled.len());
    let batch_cls = Priority::Batch.index();
    assert_eq!(sched.classes[batch_cls].cancelled as usize, cancelled.len());
}

#[test]
fn preemption_trace_resumes_byte_identically_and_leaks_nothing() {
    // The tentpole's trace tier: 2x oversubscription (4 sessions in
    // flight over 2 KV slots) on the adversarial mix, whose tight-
    // deadline High requests land while Batch floods hold every slot —
    // exactly the preemption trigger. Contract: zero capacity
    // rejections, preemptions really happen, every session's bytes
    // (preempted-then-resumed ones included) equal the uncontended
    // sequential reference, preempted ids match resumed ids, and every
    // KV slot and spill ticket is accounted for at the end.
    const SLOTS: usize = 2;
    let events = generate(&spec(Mix::AdversarialLongPrompt, 40));
    let reference = sequential_reference(&events);
    let mut sched = Scheduler::with_config(StubEngine::spilling(SLOTS), 2 * SLOTS, edf_cfg());
    assert_eq!(sched.max_sessions(), 2 * SLOTS, "oversubscription refused");
    sched.set_virtual_now_ms(0);
    let mut now = 0u64;
    let mut next_ev = 0usize;
    let mut tokens: HashMap<u64, Vec<u32>> = HashMap::new();
    let mut preempted: Vec<u64> = Vec::new();
    let mut resumed: Vec<u64> = Vec::new();
    let mut parked_now: HashSet<u64> = HashSet::new();
    loop {
        while next_ev < events.len() && events[next_ev].at_ms <= now {
            sched.submit(events[next_ev].to_request());
            next_ev += 1;
        }
        if sched.is_idle() {
            if next_ev >= events.len() {
                break;
            }
            now = events[next_ev].at_ms;
            sched.set_virtual_now_ms(now);
            continue;
        }
        let r = sched.tick();
        now += r.steps_run as u64;
        sched.set_virtual_now_ms(now);
        for ev in &r.events {
            match ev {
                SessionEvent::Preempted { id } => {
                    preempted.push(*id);
                    assert!(parked_now.insert(*id), "{id} preempted while parked");
                }
                SessionEvent::Resumed { id } => {
                    resumed.push(*id);
                    assert!(parked_now.remove(id), "{id} resumed but never parked");
                }
                SessionEvent::Token { id, .. } => {
                    assert!(!parked_now.contains(id), "parked {id} produced a token");
                }
                _ => {}
            }
        }
        for o in r.outcomes {
            match o {
                Outcome::Done(c) => {
                    tokens.insert(c.response.id, c.response.tokens);
                }
                Outcome::Failed { id, error } => panic!("request {id} failed: {error}"),
            }
        }
    }
    assert_eq!(tokens.len(), events.len(), "lost requests");
    assert_eq!(sched.rejected, 0, "oversubscription must not reject");
    assert!(sched.preemptions > 0, "trace never exercised preemption");
    assert_eq!(sched.preemptions as usize, preempted.len());
    assert_eq!(sched.resumes as usize, resumed.len());
    // Every preempted session eventually resumed (none cancelled here).
    assert!(parked_now.is_empty(), "sessions left parked: {parked_now:?}");
    {
        let mut p = preempted.clone();
        let mut q = resumed.clone();
        p.sort_unstable();
        q.sort_unstable();
        assert_eq!(p, q, "preempted/resumed ids must pair up");
    }
    // Byte identity for everyone — the resumed sessions especially.
    for (id, toks) in &tokens {
        assert_eq!(toks, &reference[id], "request {id} bytes changed");
    }
    for id in &preempted {
        assert_eq!(
            &tokens[id], &reference[id],
            "preempted-then-resumed {id} diverged from the uncontended run"
        );
    }
    assert_eq!(sched.engine().free.len(), SLOTS, "leaked KV slots");
    assert!(sched.engine().parked.is_empty(), "leaked spill tickets");
}

#[test]
fn overlapped_restore_replay_is_byte_identical_and_leaks_nothing() {
    // Pipelined-datapath trace tier: the same 2x-oversubscribed
    // adversarial trace as the preemption test, with `overlap_restore`
    // on — the scheduler hints the engine about the readmission head at
    // the end of every turn and restores consume the prefetch. The
    // contract: hints only ever name parked tickets (asserted inside
    // the stub), at least one restore actually rides a prefetch, and
    // every session's bytes still equal the uncontended sequential
    // reference with zero leaked slots or tickets.
    const SLOTS: usize = 2;
    let events = generate(&spec(Mix::AdversarialLongPrompt, 40));
    let reference = sequential_reference(&events);
    let cfg = SchedConfig {
        overlap_restore: true,
        ..SchedConfig::default()
    };
    let mut sched = Scheduler::with_config(StubEngine::spilling(SLOTS), 2 * SLOTS, cfg);
    sched.set_virtual_now_ms(0);
    let mut now = 0u64;
    let mut next_ev = 0usize;
    let mut tokens: HashMap<u64, Vec<u32>> = HashMap::new();
    loop {
        while next_ev < events.len() && events[next_ev].at_ms <= now {
            sched.submit(events[next_ev].to_request());
            next_ev += 1;
        }
        if sched.is_idle() {
            if next_ev >= events.len() {
                break;
            }
            now = events[next_ev].at_ms;
            sched.set_virtual_now_ms(now);
            continue;
        }
        let r = sched.tick();
        now += r.steps_run as u64;
        sched.set_virtual_now_ms(now);
        for o in r.outcomes {
            match o {
                Outcome::Done(c) => {
                    tokens.insert(c.response.id, c.response.tokens);
                }
                Outcome::Failed { id, error } => panic!("request {id} failed: {error}"),
            }
        }
    }
    assert_eq!(tokens.len(), events.len(), "lost requests");
    assert!(sched.preemptions > 0, "trace never exercised preemption");
    assert!(
        sched.engine().restore_hints > 0,
        "overlap hints never fired on a preempting trace"
    );
    assert!(
        sched.engine().overlap_hits > 0,
        "no restore ever consumed a prefetch"
    );
    for (id, toks) in &tokens {
        assert_eq!(
            toks, &reference[id],
            "pipelined replay changed request {id}'s bytes"
        );
    }
    assert_eq!(sched.engine().free.len(), SLOTS, "leaked KV slots");
    assert!(sched.engine().parked.is_empty(), "leaked spill tickets");
    assert!(
        sched.engine().prefetched.is_empty(),
        "prefetches outlived their tickets"
    );
}

/// Drive a trace through the scheduler over the library stub engine
/// (plain drive-to-idle on the virtual clock, like the batched replay),
/// returning per-request bytes plus the scheduler's prefix-hit
/// counters and the engine's total forward count. Asserts zero leaks.
fn drive_stub(
    events: &[TraceEvent],
    engine: StubSessionEngine,
    slots: usize,
    cfg: SchedConfig,
) -> (HashMap<u64, Vec<u32>>, u64, u64, u64) {
    let mut sched = Scheduler::with_config(engine, slots, cfg);
    sched.set_virtual_now_ms(0);
    let mut now = 0u64;
    let mut next_ev = 0usize;
    let mut tokens: HashMap<u64, Vec<u32>> = HashMap::new();
    loop {
        while next_ev < events.len() && events[next_ev].at_ms <= now {
            sched.submit(events[next_ev].to_request());
            next_ev += 1;
        }
        if sched.is_idle() {
            if next_ev >= events.len() {
                break;
            }
            now = events[next_ev].at_ms;
            sched.set_virtual_now_ms(now);
            continue;
        }
        let r = sched.tick();
        now += r.steps_run as u64;
        sched.set_virtual_now_ms(now);
        for o in r.outcomes {
            match o {
                Outcome::Done(c) => {
                    tokens.insert(c.response.id, c.response.tokens);
                }
                Outcome::Failed { id, error } => panic!("request {id} failed: {error}"),
            }
        }
    }
    assert_eq!(sched.engine().available(), slots, "leaked KV slots");
    assert_eq!(sched.engine().parked(), 0, "leaked spill tickets");
    (
        tokens,
        sched.prefix_hits,
        sched.prefix_hit_tokens,
        sched.engine().forwards,
    )
}

#[test]
fn shared_prefix_replay_is_byte_identical_and_saves_forwards() {
    // The tentpole's trace tier: a prefix-skewed trace (half the
    // requests share a 24-token preamble) replayed through the
    // scheduler over the prefix-caching stub must produce per-request
    // bytes identical to the cold per-request reference — a prefix hit
    // changes *when* prompt tokens are fed, never *what* comes out —
    // while skipping exactly one engine forward per hit token. Both
    // runs must return every slot and ticket.
    const SLOTS: usize = 3;
    let mut events = generate(&spec(Mix::Steady, 48));
    let preamble: Vec<u32> = (0..24).map(|i| (i * 5 + 2) % VOCAB as u32).collect();
    let tagged = inject_shared_prefix(&mut events, &preamble, 1, 2);
    assert_eq!(tagged, 24, "1/2 skew over 48 events");
    let reference: HashMap<u64, Vec<u32>> = events
        .iter()
        .map(|e| (e.id, StubSessionEngine::reference_tokens(&e.prompt, e.max_new)))
        .collect();
    let (cold, cold_hits, _, cold_fwd) =
        drive_stub(&events, StubSessionEngine::new(SLOTS), SLOTS, edf_cfg());
    assert_eq!(cold, reference, "uncached replay diverged from reference");
    assert_eq!(cold_hits, 0, "no cache, no hits");
    let warm_engine = || StubSessionEngine::new(SLOTS).with_prefix_cache(32);
    let (warm, hits, hit_tokens, warm_fwd) = drive_stub(&events, warm_engine(), SLOTS, edf_cfg());
    assert_eq!(warm, reference, "prefix-hit decode changed generated bytes");
    assert!(hits >= 8, "prefix skew produced only {hits} hits");
    assert!(
        hit_tokens >= 8 * preamble.len() as u64,
        "hits too shallow: {hit_tokens} tokens over {hits} hits"
    );
    // Every hit token is a prefill forward the engine never ran.
    assert_eq!(warm_fwd + hit_tokens, cold_fwd, "forward savings must equal hit tokens exactly");
    // And the cached replay is as deterministic as the cold one.
    let again = drive_stub(&events, warm_engine(), SLOTS, edf_cfg());
    assert_eq!(again, (warm, hits, hit_tokens, warm_fwd));
}

#[test]
fn pipelined_prefix_replay_matches_serial_scheduling() {
    // Prefix-cache leg of the pipelined byte-equality contract: with
    // `overlap_restore` on, a trace that never parks a session must
    // replay exactly as it does under the default config — the hint
    // path has to be inert, not merely harmless.
    const SLOTS: usize = 3;
    let mut events = generate(&spec(Mix::Steady, 48));
    let preamble: Vec<u32> = (0..24).map(|i| (i * 5 + 2) % VOCAB as u32).collect();
    inject_shared_prefix(&mut events, &preamble, 1, 2);
    let warm = || StubSessionEngine::new(SLOTS).with_prefix_cache(32);
    let serial = drive_stub(&events, warm(), SLOTS, edf_cfg());
    let pipelined = drive_stub(
        &events,
        warm(),
        SLOTS,
        SchedConfig {
            overlap_restore: true,
            ..SchedConfig::default()
        },
    );
    assert_eq!(
        pipelined, serial,
        "overlap hints changed the prefix-cache replay"
    );
}

#[test]
fn chunked_edf_beats_round_robin_p99_ttft_for_high_priority() {
    // The acceptance bar: same adversarial long-prompt trace, same
    // virtual clock, two policies. High-priority short requests must
    // see strictly lower p99 TTFT under chunked-prefill EDF than under
    // PR 1's FIFO round-robin.
    let events = generate(&spec(Mix::AdversarialLongPrompt, 100));
    let high_ids: Vec<u64> = events
        .iter()
        .filter(|e| e.priority == Priority::High)
        .map(|e| e.id)
        .collect();
    assert!(high_ids.len() >= 20, "trace too thin: {}", high_ids.len());
    let edf = replay(&events, edf_cfg(), 2);
    let rr = replay(&events, rr_cfg(), 2);
    let ttfts = |rep: &Replay| -> Vec<u64> {
        high_ids
            .iter()
            .map(|id| rep.ttft_ms[id] - rep.submit_ms[id])
            .collect()
    };
    let (edf_p99, rr_p99) = (p99(ttfts(&edf)), p99(ttfts(&rr)));
    assert!(
        edf_p99 < rr_p99,
        "chunked-prefill EDF p99 TTFT {edf_p99} ms must undercut round-robin {rr_p99} ms"
    );
    // The win should be structural, not marginal: the flood's long
    // prompts are what round-robin makes the high class wait behind.
    assert!(
        edf_p99 * 2 <= rr_p99,
        "expected a structural gap, got EDF {edf_p99} vs RR {rr_p99}"
    );
    // And batch work still finishes under EDF (no starvation-collapse).
    assert_eq!(
        edf.classes[Priority::Batch.index()].completed,
        rr.classes[Priority::Batch.index()].completed
    );
}

// --------------------------------------------------------------- fleet

/// KV geometry of the fleet engine: enough positions for DecodeHeavy's
/// deepest session (8 prompt + 64 generated), D values per token per
/// layer plane.
const FLEET_MAX_POS: usize = 96;
const FLEET_D: usize = 2;

/// The KV row a correct engine must hold for `(session, pos)` — a pure
/// function both replicas can recompute, so imported KV is verified row
/// by row on the destination instead of being taken on faith.
fn fleet_row(id: u64, pos: usize) -> f32 {
    id as f32 * 100.0 + pos as f32 * 0.5
}

/// Fleet engine over the real tiered [`KvStore`]: every forward first
/// re-verifies every previously written row of its slot — so a session
/// that just migrated proves the bytes that travelled through the
/// checksummed M2KV handoff record are exactly what the source wrote —
/// then writes the row for the current position. Logits reuse the
/// stub's pure `(token, pos)` function, keeping outputs byte-comparable
/// to a single-replica reference.
struct FleetKvEngine {
    kv: KvStore,
    rows_verified: u64,
}

impl FleetKvEngine {
    fn new(slots: usize) -> FleetKvEngine {
        // A roomy DRAM spill budget: handoff exports park in DRAM, so
        // this exercises the CRC-verified DRAM export path (the chaos
        // tier covers the SSD record path).
        FleetKvEngine {
            kv: KvStore::new(slots, 2, FLEET_MAX_POS * FLEET_D, 1 << 20),
            rows_verified: 0,
        }
    }
}

impl SessionEngine for FleetKvEngine {
    fn capacity(&self) -> usize {
        self.kv.capacity()
    }

    fn open(&mut self, req: Request) -> Result<DecodeSession> {
        anyhow::ensure!(!req.prompt.is_empty(), "empty prompt");
        let slot = self
            .kv
            .acquire()
            .ok_or_else(|| anyhow::anyhow!("kv pool exhausted"))?;
        Ok(DecodeSession::new(req, slot))
    }

    fn forward(&mut self, s: &DecodeSession, token: u32) -> Result<Vec<f32>> {
        assert!(s.pos() < FLEET_MAX_POS, "session outgrew the KV geometry");
        for p in 0..s.pos() {
            let want = fleet_row(s.id, p);
            for layer in 0..2 {
                let k = &self.kv.k_layer(s.slot(), layer)[p * FLEET_D..(p + 1) * FLEET_D];
                let v = &self.kv.v_layer(s.slot(), layer)[p * FLEET_D..(p + 1) * FLEET_D];
                assert!(
                    k.iter().all(|&x| x == want) && v.iter().all(|&x| x == -want),
                    "session {} row {p} corrupt after handoff",
                    s.id
                );
            }
            self.rows_verified += 1;
        }
        let val = fleet_row(s.id, s.pos());
        let (k_row, v_row) = ([val; FLEET_D], [-val; FLEET_D]);
        for layer in 0..2 {
            self.kv.write_token(s.slot(), layer, s.pos(), FLEET_D, &k_row, &v_row);
        }
        let mut logits = vec![0.0f32; VOCAB];
        logits[((token as usize).wrapping_mul(31) + s.pos() * 7 + 1) % VOCAB] = 1.0;
        Ok(logits)
    }

    fn close(&mut self, s: &mut DecodeSession) {
        self.kv.release(s.slot());
    }

    fn supports_handoff(&self) -> bool {
        true
    }

    fn export_kv(&mut self, s: &mut DecodeSession) -> Result<HandoffRecord> {
        let ticket = self.kv.park_prefix_copy(s.slot(), s.pos() * FLEET_D)?;
        let bytes = match self.kv.export_record(ticket) {
            Ok(b) => b,
            Err(e) => {
                self.kv.discard(ticket);
                return Err(e);
            }
        };
        self.kv.release(s.slot());
        Ok(HandoffRecord {
            session_id: s.id,
            used: s.pos(),
            kv_bytes: bytes.len() as u64,
            bytes,
        })
    }

    fn import_kv(&mut self, s: &mut DecodeSession, rec: &HandoffRecord) -> Result<()> {
        anyhow::ensure!(rec.session_id == s.id, "handoff record for wrong session");
        let ticket = self.kv.import_record(&rec.bytes)?;
        match self.kv.restore(ticket) {
            Ok(slot) => {
                s.rebind_slot(slot);
                Ok(())
            }
            Err(e) => {
                self.kv.discard(ticket);
                Err(e)
            }
        }
    }
}

/// Single-replica reference: each request alone on a one-slot engine.
fn fleet_reference(events: &[TraceEvent]) -> Vec<(u64, Vec<u32>)> {
    let mut eng = FleetKvEngine::new(1);
    let mut out = Vec::new();
    for ev in events {
        let mut s = eng.open(ev.to_request()).unwrap();
        while !s.is_done() {
            s.step(&mut eng).unwrap();
        }
        eng.close(&mut s);
        out.push((s.id, s.generated.clone()));
    }
    out.sort_by_key(|(id, _)| *id);
    out
}

#[test]
fn fleet_forced_handoff_replay_is_byte_identical_with_zero_leaks() {
    // The fleet tentpole's trace tier: every session migrates between
    // replicas mid-decode exactly once (force_handoff with a budget of
    // one), its KV rows travelling as a checksummed M2KV record over
    // the replica link. Contract: the destination re-verifies every
    // imported row on its next forward, outputs are byte-identical to
    // the single-replica reference, and both replicas end with zero
    // held slots and zero parked tickets.
    const N: usize = 12;
    let events = generate(&TraceSpec {
        mix: Mix::DecodeHeavy,
        n: N,
        seed: 0xF1EE7,
        vocab: VOCAB as u32,
    });
    let reference = fleet_reference(&events);
    let mut fleet = Fleet::new(FleetConfig {
        force_handoff: true,
        handoff_after: 1,
        min_remaining: 1,
        ..FleetConfig::default()
    });
    let a100 = find_gpu("A100").unwrap();
    let m40 = find_gpu("M40").unwrap();
    // N slots per replica: admission never queues and the peer always
    // has a free slot, so the forced migration of every session is
    // structurally guaranteed rather than load-dependent.
    fleet.add_replica(FleetKvEngine::new(N), a100, PhaseCost::uniform(1.0));
    fleet.add_replica(FleetKvEngine::new(N), m40, PhaseCost::uniform(2.0));
    let report = fleet.run_trace(&events).unwrap();
    assert_eq!(
        report.counters.handoffs,
        N as u64,
        "every session must hand off exactly once: {:?}",
        report.counters
    );
    assert!(report.counters.handoff_bytes > 0, "records carried no bytes");
    assert_eq!(report.counters.handoff_aborts, 0, "clean stores must not abort");
    assert_eq!(report.counters.handoff_recoveries, 0, "clean stores must not recompute");
    assert_eq!(fleet.outputs(), reference, "handoff changed generated bytes");
    for r in 0..2 {
        assert_eq!(fleet.engine(r).kv.in_use(), 0, "replica {r} leaked KV slots");
        assert_eq!(fleet.engine(r).kv.spilled(), 0, "replica {r} leaked tickets");
        assert!(fleet.engine(r).rows_verified > 0, "replica {r} verified nothing");
    }
    // Handoff accounting balances across the per-replica rows.
    let rows = report.counters.live();
    assert_eq!(rows.iter().map(|r| r.handoffs_out).sum::<u64>(), N as u64);
    assert_eq!(rows.iter().map(|r| r.handoffs_in).sum::<u64>(), N as u64);
}
