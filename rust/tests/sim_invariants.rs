//! Cross-cutting invariants of the simulated stack, run as randomized
//! property sweeps over configurations (from-scratch `util::check`
//! harness; proptest is unavailable offline).

use m2cache::baseline::ZeroInfinityEngine;
use m2cache::coordinator::{EngineConfig, PolicyKind, SimEngine};
use m2cache::memsim::HardwareSpec;
use m2cache::model::spec::ModelSpec;
use m2cache::precision::plan::PrecisionRatios;
use m2cache::util::check::Check;
use m2cache::util::rng::Rng;

fn random_config(rng: &mut Rng) -> EngineConfig {
    let fp16 = 0.02 + 0.08 * rng.f64();
    let int8 = 0.02 + 0.08 * rng.f64();
    let int4 = 0.05 + 0.15 * rng.f64();
    let mut cfg = EngineConfig::full();
    cfg.ratios = PrecisionRatios::new(fp16, int8, int4);
    cfg.policy = [PolicyKind::Atu, PolicyKind::Lru, PolicyKind::SlidingWindow(2)]
        [rng.range(0, 3)];
    cfg.use_ssd = rng.chance(0.7);
    cfg.use_hbm_cache = rng.chance(0.8);
    cfg.dram_capacity = (8 + rng.below(48)) << 30;
    cfg.fixed_layers = rng.range(0, 4);
    cfg.preload_depth = rng.range(1, 4);
    cfg.seed = rng.next_u64();
    cfg.trace_overlap = 0.5 + 0.45 * rng.f64();
    cfg
}

fn spec_of(rng: &mut Rng) -> ModelSpec {
    match rng.range(0, 3) {
        0 => ModelSpec::llama2_7b(),
        1 => ModelSpec::llama2_13b(),
        _ => ModelSpec::falcon_40b(),
    }
}

#[test]
fn sim_engine_invariants_hold_across_configs() {
    let gpu = m2cache::carbon::find_gpu("RTX3090").unwrap();
    Check::new(12, 0x51B).run("sim engine invariants", |rng| {
        let spec = spec_of(rng);
        let cfg = random_config(rng);
        let dram_cap = cfg.dram_capacity;
        let use_ssd = cfg.use_ssd;
        let mut e = SimEngine::new(spec, HardwareSpec::rtx3090_testbed(), cfg);
        let r = e.run(rng.range(2, 16), rng.range(2, 10), gpu);

        if r.tokens_per_s <= 0.0 {
            return Err("non-positive throughput".into());
        }
        if r.ttft_s <= 0.0 || r.ttft_s > r.total_s + 1e-9 {
            return Err(format!("ttft {} vs total {}", r.ttft_s, r.total_s));
        }
        // Telemetry conservation: hits + misses == total plan entries.
        let t = &r.telemetry;
        if t.cache_hits + t.cache_misses == 0 {
            return Err("no cache activity recorded".into());
        }
        // SSD traffic only exists with the SSD tier.
        if !use_ssd && t.traffic.ssd_to_dram != 0 {
            return Err("ssd traffic without ssd tier".into());
        }
        // DRAM stays within (configured or model-pinned) bounds; with
        // the SSD tier it must respect the user capacity.
        if use_ssd && t.peak_dram_bytes > dram_cap.max(8 << 30) * 2 {
            return Err(format!(
                "dram {} far exceeds cap {}",
                t.peak_dram_bytes, dram_cap
            ));
        }
        if r.carbon.total_g() <= 0.0 {
            return Err("zero carbon".into());
        }
        Ok(())
    });
}

#[test]
fn more_overlap_never_hurts_throughput() {
    let gpu = m2cache::carbon::find_gpu("RTX3090").unwrap();
    let run = |overlap: f64| {
        let mut cfg = EngineConfig::full();
        cfg.trace_overlap = overlap;
        let mut e = SimEngine::new(
            ModelSpec::llama2_7b(),
            HardwareSpec::rtx3090_testbed(),
            cfg,
        );
        e.run(8, 16, gpu).tokens_per_s
    };
    let lo = run(0.5);
    let hi = run(0.95);
    assert!(
        hi > lo,
        "higher token overlap must help the ATU cache: {lo} vs {hi}"
    );
}

#[test]
fn zero_infinity_throughput_independent_of_output_phrasing() {
    // Dense streaming has no cache: per-token rate is flat in sequence
    // length (modulo KV growth, negligible here).
    let gpu = m2cache::carbon::find_gpu("RTX3090").unwrap();
    let hw = HardwareSpec::rtx3090_testbed();
    let mut a = ZeroInfinityEngine::new(ModelSpec::llama2_7b(), hw.clone(), 64 << 30);
    let ra = a.run(8, 8, gpu);
    let mut b = ZeroInfinityEngine::new(ModelSpec::llama2_7b(), hw, 64 << 30);
    let rb = b.run(8, 32, gpu);
    let rel = (ra.tokens_per_s - rb.tokens_per_s).abs() / ra.tokens_per_s;
    assert!(rel < 0.05, "{} vs {}", ra.tokens_per_s, rb.tokens_per_s);
}

#[test]
fn bigger_models_are_slower_everywhere() {
    let gpu = m2cache::carbon::find_gpu("RTX3090").unwrap();
    let hw = HardwareSpec::rtx3090_testbed();
    let mut rates = Vec::new();
    for spec in [
        ModelSpec::llama2_7b(),
        ModelSpec::llama2_13b(),
        ModelSpec::llama2_70b(),
    ] {
        let mut e = SimEngine::new(spec, hw.clone(), EngineConfig::full());
        rates.push(e.run(4, 8, gpu).tokens_per_s);
    }
    assert!(rates[0] > rates[1] && rates[1] > rates[2], "{rates:?}");
}

#[test]
fn carbon_scales_with_generation_length() {
    let gpu = m2cache::carbon::find_gpu("RTX3090").unwrap();
    let hw = HardwareSpec::rtx3090_testbed();
    let mut short = SimEngine::new(ModelSpec::llama2_13b(), hw.clone(), EngineConfig::full());
    let rs = short.run(8, 8, gpu);
    let mut long = SimEngine::new(ModelSpec::llama2_13b(), hw, EngineConfig::full());
    let rl = long.run(8, 64, gpu);
    assert!(rl.carbon.total_g() > 2.0 * rs.carbon.total_g());
}
