//! Scheduler fairness/determinism tier — runs WITHOUT `make artifacts`.
//! A deterministic stub engine stands in for the PJRT stack, so these
//! tests pin the scheduling contract itself: interleaved execution
//! yields exactly the tokens sequential execution would, untagged
//! admission is FIFO, and no session starves (turns between a session's
//! steps are bounded by the number of co-active sessions). Under the
//! default policy a *turn* may feed several prompt tokens (chunked
//! prefill), so step accounting sums `TickReport::steps_run`. The
//! priority/deadline side of the policy is pinned by the trace-replay
//! tier (`rust/tests/trace_replay.rs`).

use anyhow::Result;
use m2cache::coordinator::{
    DecodeSession, Outcome, Request, Scheduler, SessionEngine,
};
use m2cache::util::rng::Rng;
use std::collections::HashMap;

const VOCAB: usize = 97;

/// Deterministic stub engine: the next token is a pure function of the
/// fed token and the session's position, so any correct scheduler must
/// reproduce the same per-session output regardless of interleaving.
/// Slots come from a free list like the real KvPool, so a session
/// handed another session's live slot would trip the close() assert.
struct StubEngine {
    slots: usize,
    free: Vec<usize>,
    /// Admission order observed by the engine (open() call order).
    open_order: Vec<u64>,
    /// Total forward passes (one per scheduler step).
    forwards: u64,
}

impl StubEngine {
    fn new(slots: usize) -> StubEngine {
        StubEngine {
            slots,
            free: (0..slots).rev().collect(),
            open_order: Vec::new(),
            forwards: 0,
        }
    }
}

impl SessionEngine for StubEngine {
    fn capacity(&self) -> usize {
        self.slots
    }

    fn open(&mut self, req: Request) -> Result<DecodeSession> {
        anyhow::ensure!(!req.prompt.is_empty(), "empty prompt");
        let slot = self
            .free
            .pop()
            .ok_or_else(|| anyhow::anyhow!("kv pool exhausted"))?;
        self.open_order.push(req.id);
        Ok(DecodeSession::new(req, slot))
    }

    fn forward(&mut self, s: &DecodeSession, token: u32) -> Result<Vec<f32>> {
        self.forwards += 1;
        assert!(
            !self.free.contains(&s.slot()),
            "session {} stepped on a freed slot {}",
            s.id,
            s.slot()
        );
        let mut logits = vec![0.0f32; VOCAB];
        let next = ((token as usize).wrapping_mul(31) + s.pos() * 7 + 1) % VOCAB;
        logits[next] = 1.0;
        Ok(logits)
    }

    fn close(&mut self, s: &mut DecodeSession) {
        assert!(
            !self.free.contains(&s.slot()),
            "double release of slot {}",
            s.slot()
        );
        self.free.push(s.slot());
    }
}

fn req(id: u64, prompt: &[u32], max_new: usize) -> Request {
    Request::new(id, prompt.to_vec(), max_new)
}

fn workload() -> Vec<(u64, Vec<u32>, usize)> {
    vec![
        (1, vec![3, 1, 4, 1, 5], 9),
        (2, vec![2, 7], 18),
        (3, vec![6, 6, 6, 6, 6, 6, 6, 6], 2),
        (4, vec![9], 12),
    ]
}

/// Run a workload at a given concurrency; returns tokens per request
/// id, the order sessions got turns in, and total engine steps run.
fn run_at(
    concurrency: usize,
    work: &[(u64, Vec<u32>, usize)],
) -> (HashMap<u64, Vec<u32>>, Vec<u64>, usize) {
    let mut sched = Scheduler::new(StubEngine::new(concurrency), concurrency);
    for (id, prompt, max_new) in work {
        sched.submit(req(*id, prompt, *max_new));
    }
    let mut tokens = HashMap::new();
    let mut stepped = Vec::new();
    let mut steps = 0;
    while !sched.is_idle() {
        let r = sched.tick();
        if let Some(id) = r.stepped {
            stepped.push(id);
        }
        steps += r.steps_run;
        for o in r.outcomes {
            match o {
                Outcome::Done(c) => {
                    tokens.insert(c.response.id, c.response.tokens);
                }
                Outcome::Failed { id, error } => panic!("req {id} failed: {error}"),
            }
        }
    }
    assert_eq!(
        sched.engine().forwards as usize, steps,
        "TickReport steps must equal engine forwards"
    );
    (tokens, stepped, steps)
}

#[test]
fn interleaved_execution_matches_sequential() {
    let work = workload();
    let (seq, _, _) = run_at(1, &work);
    for k in [2, 3, 4] {
        let (inter, _, _) = run_at(k, &work);
        assert_eq!(seq, inter, "K={k} interleaving changed outputs");
    }
    // And the outputs are what a bare session produces, one at a time.
    let mut eng = StubEngine::new(1);
    for (id, prompt, max_new) in &work {
        let mut s = eng.open(req(*id, prompt, *max_new)).unwrap();
        while !s.is_done() {
            s.step(&mut eng).unwrap();
        }
        let mut done = s;
        eng.close(&mut done);
        assert_eq!(seq[id], done.generated, "req {id} diverged from bare session");
    }
}

#[test]
fn admission_order_is_fifo() {
    for concurrency in [1, 2, 4] {
        let mut sched = Scheduler::new(StubEngine::new(concurrency), concurrency);
        for id in 1..=6u64 {
            // Varying lengths so completions happen out of submit order.
            sched.submit(req(id, &[id as u32], 1 + (id as usize * 3) % 7));
        }
        sched.run_until_idle();
        assert_eq!(
            sched.engine().open_order,
            vec![1, 2, 3, 4, 5, 6],
            "concurrency {concurrency} broke FIFO admission"
        );
    }
}

#[test]
fn no_session_starves() {
    // Between consecutive turns of any session, at most `active - 1`
    // other turns may run — the scheduler's fairness bound for
    // untagged traffic.
    let work = workload();
    let k = work.len();
    let (_, stepped, _) = run_at(k, &work);
    let mut last_seen: HashMap<u64, usize> = HashMap::new();
    for (i, id) in stepped.iter().enumerate() {
        if let Some(&prev) = last_seen.get(id) {
            let gap = i - prev; // 1 == immediate next step
            assert!(
                gap <= k,
                "session {id} waited {gap} steps (> {k} active) at step {i}: {stepped:?}"
            );
        }
        last_seen.insert(*id, i);
    }
}

#[test]
fn scheduling_is_deterministic() {
    let work = workload();
    let (t1, s1, n1) = run_at(3, &work);
    let (t2, s2, n2) = run_at(3, &work);
    assert_eq!(t1, t2, "token outputs must not vary run to run");
    assert_eq!(s1, s2, "turn order must not vary run to run");
    assert_eq!(n1, n2, "step counts must not vary run to run");
}

#[test]
fn aggregate_token_accounting_matches_per_session_sum() {
    let work = workload();
    let expected: usize = work.iter().map(|(_, _, n)| *n).sum();
    let (tokens, _, _) = run_at(3, &work);
    let total: usize = tokens.values().map(Vec::len).sum();
    assert_eq!(total, expected);
    for (id, prompt, max_new) in &work {
        assert_eq!(tokens[id].len(), *max_new);
        assert!(tokens[id].iter().all(|&t| (t as usize) < VOCAB));
        let _ = prompt;
    }
}

#[test]
fn per_request_latency_stats_are_reported() {
    let mut sched = Scheduler::new(StubEngine::new(2), 2);
    for id in 1..=3u64 {
        sched.submit(req(id, &[1, 2, 3], 4));
    }
    let outs = sched.run_until_idle();
    assert_eq!(outs.len(), 3);
    for o in outs {
        let Outcome::Done(c) = o else { panic!("unexpected failure") };
        assert!(c.response.queue_s >= 0.0);
        assert!(c.response.ttft_s >= c.response.queue_s);
        assert!(c.response.total_s >= c.response.ttft_s);
        assert_eq!(c.stats.steps, 3 + 3); // prompt feeds + decode feeds
        assert!(c.stats.max_inter_token_s >= 0.0);
    }
}

#[test]
fn rejected_requests_fail_fast_and_leak_nothing() {
    let mut sched = Scheduler::new(StubEngine::new(2), 2);
    sched.submit(req(1, &[], 4)); // invalid: empty prompt
    sched.submit(req(2, &[5], 4));
    sched.submit(req(3, &[], 4)); // invalid: empty prompt
    let outs = sched.run_until_idle();
    let failed: Vec<u64> = outs
        .iter()
        .filter(|o| matches!(o, Outcome::Failed { .. }))
        .map(|o| o.id())
        .collect();
    assert_eq!(failed, vec![1, 3]);
    let done: Vec<u64> = outs
        .iter()
        .filter(|o| matches!(o, Outcome::Done(_)))
        .map(|o| o.id())
        .collect();
    assert_eq!(done, vec![2]);
    assert_eq!(
        sched.engine().free.len(),
        2,
        "failed opens must not hold slots"
    );
}

#[test]
fn randomized_workloads_interleave_transparently() {
    // Property sweep: any workload, any concurrency — interleaving
    // never changes tokens and the engine sees one forward per step.
    let mut rng = Rng::new(0x5C4ED);
    for case in 0..25 {
        let n_reqs = rng.range(1, 7);
        let work: Vec<(u64, Vec<u32>, usize)> = (0..n_reqs)
            .map(|i| {
                let plen = rng.range(1, 9);
                let prompt: Vec<u32> =
                    (0..plen).map(|_| rng.below(VOCAB as u64) as u32).collect();
                (i as u64 + 1, prompt, rng.range(1, 12))
            })
            .collect();
        let (seq, _, _) = run_at(1, &work);
        let k = rng.range(2, 6);
        let (inter, _, steps) = run_at(k, &work);
        assert_eq!(seq, inter, "case {case} (K={k}) diverged");
        // Chunked prefill packs several steps into one turn, but the
        // engine must still see exactly one forward per session step.
        let total_steps: usize = work
            .iter()
            .map(|(_, p, n)| p.len() + n.saturating_sub(1))
            .sum();
        assert_eq!(steps, total_steps, "case {case} step count");
    }
}
