//! KvPool/KvStore property tier — runs WITHOUT `make artifacts`.
//! Random acquire/release/zero/write sequences against a shadow model,
//! in the same `util::check` style as the CacheUnit property sweeps:
//! the pool must never alias two live slots, always satisfy
//! `in_use + available == capacity`, and hand back zeroed memory on
//! every (re-)acquire. The tiered-store sweeps extend the op set with
//! spill/restore/discard: parked state must round-trip byte-
//! identically through whichever spill tier (DRAM area or SSD file)
//! took it, and no slot or ticket may ever leak.

use m2cache::coordinator::{FaultConfig, KvPool, KvStore, KvTicket, SpillTier};
use m2cache::util::check::Check;
use m2cache::util::rng::Rng;
use std::collections::{BTreeSet, HashMap};

/// One random op sequence against a freshly built pool.
fn pool_invariants(rng: &mut Rng) -> Result<(), String> {
    let slots = rng.range(1, 6);
    let layers = rng.range(1, 4);
    let d = rng.range(1, 5);
    let max_seq = rng.range(1, 6);
    let stride = max_seq * d;
    let mut pool = KvPool::new(slots, layers, stride);
    if pool.bytes() != (2 * slots * layers * stride * 4) as u64 {
        return Err(format!("bytes() wrong for {slots}x{layers}x{stride}"));
    }
    // Shadow model: the set of live slots, plus a per-slot sentinel we
    // wrote (slot -> (layer, pos, value)).
    let mut live: BTreeSet<usize> = BTreeSet::new();
    let mut wrote: Vec<Option<(usize, usize, f32)>> = vec![None; slots];
    for step in 0..64 {
        match rng.below(4) {
            0 => {
                // Acquire: unique, zeroed, or None exactly at capacity.
                match pool.acquire() {
                    Some(s) => {
                        if s >= slots {
                            return Err(format!("slot {s} out of range"));
                        }
                        if !live.insert(s) {
                            return Err(format!("step {step}: slot {s} double-acquired"));
                        }
                        for l in 0..layers {
                            if pool.k_layer(s, l).iter().any(|&x| x != 0.0)
                                || pool.v_layer(s, l).iter().any(|&x| x != 0.0)
                            {
                                return Err(format!("step {step}: slot {s} not zeroed"));
                            }
                        }
                        wrote[s] = None;
                    }
                    None => {
                        if live.len() != slots {
                            return Err(format!(
                                "step {step}: pool refused with {} free",
                                slots - live.len()
                            ));
                        }
                    }
                }
            }
            1 => {
                // Release a random live slot.
                if let Some(&s) = live.iter().next() {
                    live.remove(&s);
                    pool.release(s);
                    wrote[s] = None;
                }
            }
            2 => {
                // Write a sentinel row into a random live slot.
                if !live.is_empty() {
                    let pick = rng.range(0, live.len());
                    let s = *live.iter().nth(pick).expect("picked live slot");
                    let layer = rng.range(0, layers);
                    let pos = rng.range(0, max_seq);
                    let val = (step + 1) as f32;
                    pool.write_token(s, layer, pos, d, &vec![val; d], &vec![-val; d]);
                    wrote[s] = Some((layer, pos, val));
                }
            }
            _ => {
                // Zero a random live slot.
                if let Some(&s) = live.iter().last() {
                    pool.zero(s);
                    wrote[s] = None;
                }
            }
        }
        // Invariants after every op.
        if pool.in_use() + pool.available() != pool.capacity() {
            return Err(format!(
                "step {step}: in_use {} + available {} != capacity {}",
                pool.in_use(),
                pool.available(),
                pool.capacity()
            ));
        }
        if pool.in_use() != live.len() {
            return Err(format!(
                "step {step}: pool thinks {} in use, model says {}",
                pool.in_use(),
                live.len()
            ));
        }
        // No aliasing: every live slot still reads back its own
        // sentinel (another slot's write or zero must never leak in).
        for &s in &live {
            if let Some((layer, pos, val)) = wrote[s] {
                let k = &pool.k_layer(s, layer)[pos * d..pos * d + d];
                let v = &pool.v_layer(s, layer)[pos * d..pos * d + d];
                if k.iter().any(|&x| x != val) || v.iter().any(|&x| x != -val) {
                    return Err(format!(
                        "step {step}: slot {s} sentinel clobbered (k {k:?} v {v:?})"
                    ));
                }
            }
        }
    }
    Ok(())
}

#[test]
fn kv_pool_random_ops_never_alias_and_conserve_slots() {
    Check::new(200, 0x5107).run("kv-pool-invariants", pool_invariants);
}

/// Random spill/restore/discard sequences against a shadow model: the
/// tiered store must conserve slots, track exactly the outstanding
/// tickets, and restore each parked session's sentinel bit-exactly.
fn kv_store_spill_invariants(rng: &mut Rng) -> Result<(), String> {
    let slots = rng.range(1, 5);
    let layers = rng.range(1, 4);
    let d = rng.range(1, 4);
    let max_seq = rng.range(1, 5);
    let stride = max_seq * d;
    let slot_bytes = (2 * layers * stride * 4) as u64;
    // Three budget regimes: everything to the SSD file, a one-slot
    // DRAM area that cascades, and DRAM-only.
    let budget = [0, slot_bytes, u64::MAX / 2][rng.below(3) as usize];
    let mut kv = KvStore::new(slots, layers, stride, budget);
    let mut live: Vec<usize> = Vec::new();
    // slot -> sentinel (layer, pos, val) last written.
    let mut wrote: HashMap<usize, (usize, usize, f32)> = HashMap::new();
    // Outstanding tickets with the sentinel their state must carry.
    let mut parked: Vec<(KvTicket, Option<(usize, usize, f32)>)> = Vec::new();
    for step in 0..96 {
        match rng.below(6) {
            0 => {
                if let Some(s) = kv.acquire() {
                    if live.contains(&s) {
                        return Err(format!("step {step}: slot {s} double-acquired"));
                    }
                    live.push(s);
                }
            }
            1 => {
                if !live.is_empty() {
                    let s = live.swap_remove(rng.range(0, live.len()));
                    kv.release(s);
                    wrote.remove(&s);
                }
            }
            2 => {
                if !live.is_empty() {
                    let s = live[rng.range(0, live.len())];
                    let layer = rng.range(0, layers);
                    let pos = rng.range(0, max_seq);
                    let val = (step + 1) as f32;
                    kv.write_token(s, layer, pos, d, &vec![val; d], &vec![-val; d]);
                    wrote.insert(s, (layer, pos, val));
                }
            }
            3 => {
                if !live.is_empty() {
                    let s = live.swap_remove(rng.range(0, live.len()));
                    let t = kv.spill(s).map_err(|e| format!("step {step}: spill: {e:#}"))?;
                    parked.push((t, wrote.remove(&s)));
                }
            }
            4 => {
                // Prefix-cache-style park: copy a live slot's full
                // planes into a spill tier WITHOUT releasing the slot.
                // The ticket joins the parked set as a first-class
                // citizen (restorable, discardable) carrying a copy of
                // the sentinel as of park time; the source slot keeps
                // serving (and may later overwrite) its own.
                if !live.is_empty() {
                    let s = live[rng.range(0, live.len())];
                    let t = kv
                        .park_prefix_copy(s, stride)
                        .map_err(|e| format!("step {step}: park: {e:#}"))?;
                    if kv.in_use() != live.len() {
                        return Err(format!("step {step}: park released slot {s}"));
                    }
                    parked.push((t, wrote.get(&s).copied()));
                }
            }
            _ => {
                if !parked.is_empty() {
                    let pi = rng.range(0, parked.len());
                    let (t, sentinel) = parked.swap_remove(pi);
                    if rng.below(4) == 0 {
                        if !kv.discard(t) {
                            return Err(format!("step {step}: known ticket not discarded"));
                        }
                    } else if kv.available() == 0 {
                        // Full pool: restore must refuse AND keep the
                        // ticket redeemable.
                        if kv.restore(t).is_ok() {
                            return Err(format!("step {step}: restore into a full pool"));
                        }
                        parked.push((t, sentinel));
                    } else {
                        let s = kv
                            .restore(t)
                            .map_err(|e| format!("step {step}: restore: {e:#}"))?;
                        if live.contains(&s) {
                            return Err(format!("step {step}: restore aliased slot {s}"));
                        }
                        if let Some((layer, pos, val)) = sentinel {
                            let k = &kv.k_layer(s, layer)[pos * d..pos * d + d];
                            let v = &kv.v_layer(s, layer)[pos * d..pos * d + d];
                            if k.iter().any(|&x| x != val) || v.iter().any(|&x| x != -val) {
                                return Err(format!(
                                    "step {step}: ticket restored wrong bytes (k {k:?})"
                                ));
                            }
                            wrote.insert(s, (layer, pos, val));
                        }
                        live.push(s);
                    }
                }
            }
        }
        // Invariants after every op.
        if kv.in_use() + kv.available() != kv.capacity() {
            return Err(format!(
                "step {step}: in_use {} + available {} != capacity {}",
                kv.in_use(),
                kv.available(),
                kv.capacity()
            ));
        }
        if kv.in_use() != live.len() {
            return Err(format!(
                "step {step}: store thinks {} in use, model says {}",
                kv.in_use(),
                live.len()
            ));
        }
        if kv.spilled() != parked.len() {
            return Err(format!(
                "step {step}: store tracks {} tickets, model says {}",
                kv.spilled(),
                parked.len()
            ));
        }
        // Live sentinels never clobbered by spill/restore churn.
        for (&s, &(layer, pos, val)) in &wrote {
            let k = &kv.k_layer(s, layer)[pos * d..pos * d + d];
            if k.iter().any(|&x| x != val) {
                return Err(format!("step {step}: slot {s} sentinel clobbered"));
            }
        }
    }
    // Drain: every outstanding ticket restores cleanly, no leaks.
    for s in live.drain(..) {
        kv.release(s);
    }
    while let Some((t, _)) = parked.pop() {
        let s = kv.restore(t).map_err(|e| format!("drain restore: {e:#}"))?;
        kv.release(s);
    }
    if kv.spilled() != 0 {
        return Err(format!("{} tickets leaked after drain", kv.spilled()));
    }
    let c = *kv.counters();
    if c.spills() != c.restores() + c.discards {
        return Err(format!(
            "ticket conservation: {} spills != {} restores + {} discards",
            c.spills(),
            c.restores(),
            c.discards
        ));
    }
    Ok(())
}

#[test]
fn kv_store_random_spill_restore_discard_conserves_everything() {
    Check::new(150, 0x51F7).run("kv-store-spill-invariants", kv_store_spill_invariants);
}

/// Record recycling in the SSD spill file: steady churn of `w`
/// concurrent tickets must reuse freed records (the free list) instead
/// of appending — the file's allocation high-water mark plateaus after
/// the first round and never grows again.
#[test]
fn spill_file_high_water_plateaus_under_steady_churn() {
    // DRAM budget 0: every park lands in the SSD spill file.
    let mut kv = KvStore::new(4, 2, 8, 0);
    let w = 3usize;
    let mut high = 0usize;
    for round in 0..32 {
        let mut tickets = Vec::new();
        for i in 0..w {
            let s = kv.acquire().expect("pool has room");
            let val = (round * w + i + 1) as f32;
            kv.write_token(s, 1, 0, 2, &[val, val], &[-val, -val]);
            let t = kv.spill(s).expect("spill to file");
            assert_eq!(kv.ticket_tier(t), Some(SpillTier::Ssd), "budget 0 must hit the file");
            tickets.push((t, val));
        }
        assert_eq!(kv.ssd_parked(), w);
        // Alternate drain order so records also recycle out of order.
        if round % 2 == 1 {
            tickets.reverse();
        }
        for (t, val) in tickets {
            let s = kv.restore(t).expect("restore from file");
            let k = &kv.k_layer(s, 1)[..2];
            assert_eq!(k, [val, val], "round {round}: wrong bytes back");
            kv.release(s);
        }
        if round == 0 {
            high = kv.file_high_water();
            assert_eq!(high, w, "first round allocates one record per ticket");
        } else {
            assert_eq!(kv.file_high_water(), high, "file grew at round {round}");
        }
        assert_eq!(kv.file_free_records(), high, "records not recycled at round {round}");
        assert_eq!(kv.ssd_parked(), 0);
    }
}

/// A corrupt spill record can never round-trip. Park a sentinel
/// through each spill tier, flip one byte (sweeping every byte index
/// in the record via the test-only corruption hook), and the restore
/// must error — never hand back silently wrong bytes. The failed
/// restore leaks no slot, and the ticket stays discardable.
#[test]
fn flipping_any_byte_of_a_parked_record_fails_restore() {
    // 2 layers x stride 8 -> 128 payload bytes (+16-byte header on
    // SSD); DRAM parks sweep k-bytes + v-bytes + the stored CRC.
    let record = KvStore::new(2, 2, 8, 0).record_bytes() as usize;
    for budget in [0u64, u64::MAX / 2] {
        let expect_tier = if budget == 0 { SpillTier::Ssd } else { SpillTier::Dram };
        for byte_idx in 0..record {
            let mut kv = KvStore::new(2, 2, 8, budget);
            let s = kv.acquire().expect("pool has room");
            kv.write_token(s, 0, 0, 2, &[1.5, -2.5], &[3.5, -4.5]);
            kv.write_token(s, 1, 3, 2, &[9.0, 8.0], &[7.0, 6.0]);
            let t = kv.spill(s).expect("clean spill");
            assert_eq!(kv.ticket_tier(t), Some(expect_tier));
            assert!(kv.corrupt_parked_byte(t, byte_idx), "hook lost the ticket");
            assert!(
                kv.restore(t).is_err(),
                "byte {byte_idx} round-tripped through {expect_tier:?}"
            );
            assert!(kv.fault_counters().crc_failures >= 1, "byte {byte_idx}: CRC silent");
            assert_eq!(kv.in_use(), 0, "byte {byte_idx}: failed restore leaked a slot");
            assert!(kv.discard(t), "byte {byte_idx}: ticket lost after failed restore");
            assert_eq!(kv.spilled(), 0);
        }
    }
}

/// Publish-ordering pin: a spill ticket is only published once the
/// full record is durably on disk. With every SSD write torn (a
/// strict prefix lands, then the write errors), no ticket may ever
/// point at a torn record — the store retries, exhausts, recycles the
/// failed record allocation, and parks the state in DRAM instead,
/// byte-intact.
#[test]
fn torn_writes_never_publish_a_ticket_onto_a_torn_record() {
    let cfg = FaultConfig {
        torn_write: 1.0,
        ..FaultConfig::default()
    };
    // DRAM budget 0 forces the SSD attempt first.
    let mut kv = KvStore::new(2, 2, 8, 0).with_faults(cfg).with_retry(3, 0);
    let s = kv.acquire().expect("pool has room");
    kv.write_token(s, 1, 2, 2, &[5.0, 6.0], &[-5.0, -6.0]);
    let t = kv.spill(s).expect("spill must degrade, not fail");
    assert_eq!(
        kv.ticket_tier(t),
        Some(SpillTier::Dram),
        "ticket published against a torn SSD record"
    );
    assert_eq!(kv.ssd_parked(), 0);
    let f = kv.fault_counters();
    assert!(f.injected_torn_writes >= 3, "retries not exhausted: {f:?}");
    assert_eq!(f.degraded_spills, 1, "{f:?}");
    // The failed record allocation was recycled, not leaked.
    assert_eq!(kv.file_free_records(), kv.file_high_water());
    // And the parked bytes are intact through the fallback tier.
    let s = kv.restore(t).expect("restore from the DRAM fallback");
    assert_eq!(&kv.k_layer(s, 1)[4..6], &[5.0, 6.0]);
    assert_eq!(&kv.v_layer(s, 1)[4..6], &[-5.0, -6.0]);
}

#[test]
fn kv_pool_full_acquire_release_cycle_roundtrips() {
    Check::new(64, 0xC1C).run("kv-pool-roundtrip", |rng| {
        let slots = rng.range(1, 8);
        let mut pool = KvPool::new(slots, 2, 8);
        // Drain the pool completely: all slots distinct.
        let mut got = BTreeSet::new();
        for _ in 0..slots {
            let s = pool.acquire().ok_or("pool under-delivered")?;
            if !got.insert(s) {
                return Err(format!("duplicate slot {s}"));
            }
        }
        if pool.acquire().is_some() {
            return Err("pool over-delivered past capacity".into());
        }
        if pool.available() != 0 || pool.in_use() != slots {
            return Err("drained pool miscounts".into());
        }
        // Dirty every slot, release everything, re-drain: all zeroed.
        for &s in &got {
            pool.write_token(s, 1, 3, 2, &[9.0, 9.0], &[9.0, 9.0]);
        }
        for &s in &got {
            pool.release(s);
        }
        if pool.available() != slots || pool.in_use() != 0 {
            return Err("released pool miscounts".into());
        }
        for _ in 0..slots {
            let s = pool.acquire().ok_or("re-acquire failed")?;
            for l in 0..2 {
                if pool.k_layer(s, l).iter().any(|&x| x != 0.0)
                    || pool.v_layer(s, l).iter().any(|&x| x != 0.0)
                {
                    return Err(format!("slot {s} came back dirty"));
                }
            }
        }
        Ok(())
    });
}
