//! Streaming serving tier — runs WITHOUT `make artifacts`.
//!
//! Pins the v2 serving contract at two levels over the deterministic
//! [`StubSessionEngine`]:
//!
//! - **Core** (no sockets): token-event ordering, first token strictly
//!   before completion, mid-decode cancel returning the KV slot to the
//!   pool and evicting the session from the next turn set, continuous
//!   admission joining an in-flight batched turn.
//! - **Wire** (real TCP server, stub engine — `serve()` is generic):
//!   v1 replies byte-identical to the pre-v2 protocol, v2 `ACK`/`TOK`/
//!   `END` framing with a `TOK` observed strictly before `END`, a
//!   `CANCEL` landing mid-decode over the wire, and the
//!   snapshot-backed STATS reply.

use m2cache::coordinator::{
    server, tokenize, Request, SchedConfig, ServingCore, SessionEvent, StubSessionEngine,
};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::time::Duration;

fn req(id: u64, prompt: &str, max_new: usize) -> Request {
    Request::new(id, tokenize(prompt), max_new)
}

// ---------------------------------------------------------------- core

#[test]
fn token_events_stream_in_order_and_strictly_before_done() {
    let mut core = ServingCore::from_engine(StubSessionEngine::new(2));
    core.submit(req(1, "the quick brown fox", 6));
    core.submit(req(2, "jumps over", 4));
    let mut streamed: HashMap<u64, Vec<u32>> = HashMap::new();
    let mut first_token_tick: HashMap<u64, u64> = HashMap::new();
    let mut done_tick: HashMap<u64, u64> = HashMap::new();
    let mut finals: HashMap<u64, Vec<u32>> = HashMap::new();
    let mut tick = 0u64;
    while !core.is_idle() {
        for ev in core.pump(&mut || None) {
            match ev {
                SessionEvent::Admitted { .. } => {}
                SessionEvent::Token { id, token, index } => {
                    let s = streamed.entry(id).or_default();
                    assert_eq!(index, s.len(), "req {id}: token indices must be dense");
                    s.push(token);
                    first_token_tick.entry(id).or_insert(tick);
                }
                SessionEvent::Done(c) => {
                    done_tick.insert(c.response.id, tick);
                    finals.insert(c.response.id, c.response.tokens);
                }
                ev => panic!("unexpected event {ev:?}"),
            }
        }
        tick += 1;
    }
    for id in [1u64, 2] {
        // The tentpole's acceptance bar: a token is observable strictly
        // before decode completion.
        assert!(
            first_token_tick[&id] < done_tick[&id],
            "req {id}: first token not before completion"
        );
        // The stream and the final reply are the same bytes, and both
        // equal the solo reference (interleaving is invisible).
        assert_eq!(streamed[&id], finals[&id]);
    }
    assert_eq!(
        finals[&1],
        StubSessionEngine::reference_tokens(&tokenize("the quick brown fox"), 6)
    );
    assert_eq!(
        finals[&2],
        StubSessionEngine::reference_tokens(&tokenize("jumps over"), 4)
    );
}

#[test]
fn cancel_mid_decode_returns_slot_and_leaves_next_turn_set() {
    let cfg = SchedConfig {
        batch: true,
        ..SchedConfig::default()
    };
    let mut core = ServingCore::new(StubSessionEngine::new(2), 2, cfg);
    let pre_admit = core.scheduler().engine().available();
    assert_eq!(pre_admit, 2);
    core.submit(req(1, "abc", 64));
    core.submit(req(2, "defg", 64));
    // Run until both sessions are decoding (tokens observed from each).
    let mut seen = [0usize; 2];
    while seen[0] == 0 || seen[1] == 0 {
        for ev in core.pump(&mut || None) {
            if let SessionEvent::Token { id, .. } = ev {
                seen[id as usize - 1] += 1;
            }
        }
    }
    assert_eq!(core.scheduler().engine().available(), 0);
    // Mid-decode cancel: the slot must return to the pool immediately —
    // `available()` back up before any further tick — and the next
    // turn set must not contain the session.
    let ev = core.cancel(1).expect("session 1 is mid-decode");
    let cancelled_at = match ev {
        SessionEvent::Cancelled { id: 1, tokens } => tokens,
        ev => panic!("expected Cancelled, got {ev:?}"),
    };
    assert!(cancelled_at > 0, "cancel was supposed to land mid-decode");
    assert_eq!(
        core.scheduler().engine().available(),
        pre_admit - 1,
        "KV slot not returned on cancel"
    );
    let r = core.scheduler_mut().tick();
    assert!(
        !r.batch.contains(&1),
        "cancelled session still in the turn set: {:?}",
        r.batch
    );
    assert!(r.batch.contains(&2), "survivor missing from the turn set");
    // The survivor runs to its full budget with reference bytes.
    let events = core.run_until_idle();
    let done = events
        .iter()
        .chain(r.events.iter())
        .find_map(|e| match e {
            SessionEvent::Done(c) => Some(c.response.clone()),
            _ => None,
        })
        .expect("survivor completed");
    assert_eq!(done.id, 2);
    assert_eq!(
        done.tokens,
        StubSessionEngine::reference_tokens(&tokenize("defg"), 64)
    );
    assert_eq!(core.scheduler().engine().available(), pre_admit);
    assert_eq!(core.snapshot().cancelled, 1);
}

#[test]
fn continuous_admission_joins_inflight_turn_and_streams_same_bytes() {
    let cfg = SchedConfig {
        batch: true,
        prefill_chunk: 12,
        ..SchedConfig::default()
    };
    let mut core = ServingCore::new(StubSessionEngine::new(2), 2, cfg);
    core.submit(req(1, "a long prompt", 4)); // 13 feeds: fills most of the chunk
    // Request 2 arrives only at the second intake poll — i.e. while
    // request 1's prefill turn is already in flight.
    let mut arrivals = vec![req(2, "hi", 3)];
    let mut polls = 0;
    let events = core.pump(&mut || {
        polls += 1;
        if polls >= 2 {
            arrivals.pop()
        } else {
            None
        }
    });
    assert!(
        events
            .iter()
            .any(|e| matches!(e, SessionEvent::Admitted { id: 2 })),
        "joiner not admitted into the in-flight turn: {events:?}"
    );
    assert!(
        events
            .iter()
            .any(|e| matches!(e, SessionEvent::Token { id: 2, .. })),
        "joiner produced no token inside the joined turn: {events:?}"
    );
    // Joining mid-turn never changes anyone's bytes.
    let mut finals: HashMap<u64, Vec<u32>> = HashMap::new();
    for ev in events.into_iter().chain(core.run_until_idle()) {
        if let SessionEvent::Done(c) = ev {
            finals.insert(c.response.id, c.response.tokens);
        }
    }
    assert_eq!(
        finals[&1],
        StubSessionEngine::reference_tokens(&tokenize("a long prompt"), 4)
    );
    assert_eq!(
        finals[&2],
        StubSessionEngine::reference_tokens(&tokenize("hi"), 3)
    );
}

#[test]
fn preempted_session_parks_resumes_and_streams_identical_bytes() {
    // Oversubscribed serving core over the spill-capable stub: a High
    // request arriving to a full box preempts the Batch session, whose
    // stream pauses (Preempted), resumes (Resumed), and finishes with
    // the same bytes as an uncontended run — preemption is visible in
    // the event stream but invisible in the output.
    use m2cache::coordinator::Priority;
    let mut core = ServingCore::new(
        StubSessionEngine::new(1).with_spill(),
        2,
        SchedConfig::default(),
    );
    core.submit(
        Request::new(1, tokenize("slow batch job"), 12).with_class(Priority::Batch, None),
    );
    let mut events = Vec::new();
    for _ in 0..3 {
        events.extend(core.pump(&mut || None));
    }
    core.submit(Request::new(2, tokenize("now"), 3).with_class(Priority::High, Some(5_000)));
    events.extend(core.run_until_idle());
    let preempts: Vec<u64> = events
        .iter()
        .filter_map(|e| match e {
            SessionEvent::Preempted { id } => Some(*id),
            _ => None,
        })
        .collect();
    assert_eq!(preempts, vec![1], "{events:?}");
    assert!(events.iter().any(|e| matches!(e, SessionEvent::Resumed { id: 1 })));
    let mut finals: HashMap<u64, Vec<u32>> = HashMap::new();
    let mut streamed: HashMap<u64, Vec<u32>> = HashMap::new();
    for ev in &events {
        match ev {
            SessionEvent::Token { id, token, .. } => {
                streamed.entry(*id).or_default().push(*token)
            }
            SessionEvent::Done(c) => {
                finals.insert(c.response.id, c.response.tokens.clone());
            }
            _ => {}
        }
    }
    assert_eq!(
        finals[&1],
        StubSessionEngine::reference_tokens(&tokenize("slow batch job"), 12)
    );
    assert_eq!(
        finals[&2],
        StubSessionEngine::reference_tokens(&tokenize("now"), 3)
    );
    assert_eq!(streamed[&1], finals[&1], "stream != final across preemption");
    let snap = core.snapshot();
    assert_eq!((snap.preemptions, snap.resumes, snap.parked), (1, 1, 0));
    let engine = core.scheduler().engine();
    assert_eq!(engine.available(), 1, "slot not returned");
    assert_eq!(engine.parked(), 0, "ticket leaked");
    assert_eq!((engine.spills, engine.restores), (1, 1));
}

#[test]
fn zero_max_new_request_completes_with_no_token_events() {
    // `max_new == 0` is a legal prefill-only request: it must terminate
    // with a Done carrying zero tokens (no Token events, no hang) and
    // give its KV slot back — alongside a normal request whose bytes it
    // must not disturb.
    let mut core = ServingCore::from_engine(StubSessionEngine::new(2));
    core.submit(req(1, "just prefill me", 0));
    core.submit(req(2, "ab", 2));
    let events = core.run_until_idle();
    assert!(
        !events
            .iter()
            .any(|e| matches!(e, SessionEvent::Token { id: 1, .. })),
        "zero-budget request streamed a token: {events:?}"
    );
    let done = events
        .iter()
        .find_map(|e| match e {
            SessionEvent::Done(c) if c.response.id == 1 => Some(c.response.clone()),
            _ => None,
        })
        .expect("zero-budget request never completed");
    assert!(done.tokens.is_empty(), "{:?}", done.tokens);
    let other = events
        .iter()
        .find_map(|e| match e {
            SessionEvent::Done(c) if c.response.id == 2 => Some(c.response.clone()),
            _ => None,
        })
        .expect("neighbour never completed");
    assert_eq!(
        other.tokens,
        StubSessionEngine::reference_tokens(&tokenize("ab"), 2)
    );
    assert_eq!(core.served(), 2);
    assert_eq!(core.scheduler().engine().available(), 2, "slot leaked");
}

// ---------------------------------------------------------------- wire

/// Boot the generic server over a stub engine; returns the address and
/// the join handle (the warm engine comes back at shutdown).
fn spawn_stub_server(
    engine: StubSessionEngine,
    max: u64,
) -> (
    std::net::SocketAddr,
    std::thread::JoinHandle<StubSessionEngine>,
) {
    let (addr_tx, addr_rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        server::serve(engine, "127.0.0.1:0", Some(max), move |a| {
            let _ = addr_tx.send(a);
        })
        .unwrap()
    });
    (addr_rx.recv().unwrap(), handle)
}

fn send_line(conn: &mut TcpStream, line: &str) {
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
}

fn read_line(reader: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line.trim_end_matches('\n').to_string()
}

#[test]
fn v1_replies_are_byte_identical_to_the_legacy_protocol() {
    let (addr, handle) = spawn_stub_server(StubSessionEngine::new(2), 2);
    // Error lines: exact legacy bytes.
    {
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        send_line(&mut conn, "NONSENSE");
        assert_eq!(read_line(&mut reader), "ERR expected GEN or STATS");
        send_line(&mut conn, "GEN 8");
        assert_eq!(read_line(&mut reader), "ERR empty prompt");
        send_line(&mut conn, "GEN@vip 8 hello");
        assert_eq!(read_line(&mut reader), "ERR bad priority class");
        send_line(&mut conn, "GEN notanumber hi");
        assert_eq!(read_line(&mut reader), "ERR bad max_new");
        // CANCEL/HELLO are v2 verbs — a v1 connection keeps the legacy
        // error bytes, well-formed or not.
        send_line(&mut conn, "CANCEL 1");
        assert_eq!(read_line(&mut reader), "ERR expected GEN or STATS");
        send_line(&mut conn, "CANCEL x");
        assert_eq!(read_line(&mut reader), "ERR expected GEN or STATS");
        send_line(&mut conn, "HELLO v9");
        assert_eq!(read_line(&mut reader), "ERR expected GEN or STATS");
    }
    // GEN replies: `OK <id> <3 timings> <text>` with the stub's exact
    // reference bytes — an untouched v1 client sees the old protocol.
    for prompt in ["the quick brown fox", "hello world"] {
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        send_line(&mut conn, &format!("GEN 8 {prompt}"));
        let reply = read_line(&mut reader);
        let mut parts = reply.splitn(6, ' ');
        assert_eq!(parts.next(), Some("OK"));
        let _id: u64 = parts.next().unwrap().parse().unwrap();
        for _ in 0..3 {
            let _ms: f64 = parts.next().unwrap().parse().unwrap();
        }
        let text = parts.next().unwrap_or("");
        let expect = m2cache::coordinator::detokenize(
            &StubSessionEngine::reference_tokens(&tokenize(prompt), 8),
        );
        assert_eq!(text, expect, "v1 text changed for {prompt:?}");
    }
    handle.join().unwrap();
}

#[test]
fn v2_streams_tok_frames_strictly_before_end() {
    let (addr, handle) = spawn_stub_server(StubSessionEngine::new(2), 1);
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    send_line(&mut conn, "HELLO v2");
    assert_eq!(read_line(&mut reader), "HELLO v2");
    let prompt = "a journey of a thousand";
    send_line(&mut conn, &format!("GEN 10 {prompt}"));
    let ack = read_line(&mut reader);
    let id: u64 = ack
        .strip_prefix("ACK ")
        .unwrap_or_else(|| panic!("expected ACK, got {ack:?}"))
        .parse()
        .unwrap();
    let mut toks = Vec::new();
    let end;
    loop {
        let frame = read_line(&mut reader);
        if let Some(rest) = frame.strip_prefix("TOK ") {
            let (fid, text) = rest.split_once(' ').unwrap_or((rest, ""));
            assert_eq!(fid.parse::<u64>().unwrap(), id);
            toks.push(text.to_string());
        } else if let Some(rest) = frame.strip_prefix("END ") {
            end = rest.to_string();
            break;
        } else {
            panic!("unexpected frame {frame:?}");
        }
    }
    // The acceptance bar on the wire: at least one TOK arrived before
    // END, and the concatenated stream equals the v1 one-shot text.
    assert!(!toks.is_empty(), "END with no TOK frames");
    assert_eq!(toks.len(), 10);
    let streamed: String = toks.concat();
    let expect = m2cache::coordinator::detokenize(&StubSessionEngine::reference_tokens(
        &tokenize(prompt),
        10,
    ));
    assert_eq!(streamed, expect);
    // END carries id + the three latency figures.
    let mut parts = end.split(' ');
    assert_eq!(parts.next().unwrap().parse::<u64>().unwrap(), id);
    assert_eq!(parts.clone().count(), 3, "END {end:?}");
    for ms in parts {
        assert!(ms.parse::<f64>().unwrap() >= 0.0);
    }
    handle.join().unwrap();
}

#[test]
fn v2_cancel_lands_mid_decode_over_the_wire() {
    // 2 ms per engine forward paces the decode loop, so the CANCEL sent
    // after reading two TOK frames deterministically beats the 200-token
    // budget (~400 ms of remaining decode).
    let engine = StubSessionEngine::new(2).with_step_delay(Duration::from_millis(2));
    // max = 2 terminal replies: the CANCELLED and the follow-up END.
    let (addr, handle) = spawn_stub_server(engine, 2);
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    send_line(&mut conn, "HELLO v2");
    assert_eq!(read_line(&mut reader), "HELLO v2");
    send_line(&mut conn, "GEN 200 abcdefgh");
    let id: u64 = read_line(&mut reader)
        .strip_prefix("ACK ")
        .expect("ACK first")
        .parse()
        .unwrap();
    // Read two streamed tokens, then hang up this request.
    for _ in 0..2 {
        let frame = read_line(&mut reader);
        assert!(frame.starts_with(&format!("TOK {id} ")), "{frame:?}");
    }
    send_line(&mut conn, &format!("CANCEL {id}"));
    // Drain TOK frames already in flight until the CANCELLED ack.
    let tokens_at_cancel;
    loop {
        let frame = read_line(&mut reader);
        if let Some(rest) = frame.strip_prefix("CANCELLED ") {
            let (fid, toks) = rest.split_once(' ').expect("CANCELLED <id> <tokens>");
            assert_eq!(fid.parse::<u64>().unwrap(), id);
            tokens_at_cancel = toks.parse::<usize>().unwrap();
            break;
        }
        assert!(frame.starts_with("TOK "), "unexpected frame {frame:?}");
    }
    assert!(
        (2..200).contains(&tokens_at_cancel),
        "cancel was not mid-decode: {tokens_at_cancel} tokens"
    );
    // The server keeps serving this connection: STATS shows the cancel
    // in the snapshot, an unknown-id CANCEL answers the canceller with
    // a typed ERR (not a terminal reply), and a fresh GEN streams to
    // completion.
    send_line(&mut conn, "STATS");
    let stats = read_line(&mut reader);
    assert!(stats.contains("\"cancelled\":1"), "{stats}");
    send_line(&mut conn, "CANCEL 9999");
    assert_eq!(read_line(&mut reader), "ERR 22 9999 unknown id");
    send_line(&mut conn, "GEN 3 ok then");
    let ack = read_line(&mut reader);
    let id2: u64 = ack.strip_prefix("ACK ").unwrap().parse().unwrap();
    assert_ne!(id, id2);
    let mut got_end = false;
    let mut n_toks = 0;
    while !got_end {
        let frame = read_line(&mut reader);
        if frame.starts_with(&format!("TOK {id2} ")) {
            n_toks += 1;
        } else if frame.starts_with(&format!("END {id2} ")) {
            got_end = true;
        } else {
            panic!("unexpected frame {frame:?}");
        }
    }
    assert_eq!(n_toks, 3);
    let engine = handle.join().unwrap();
    assert_eq!(engine.available(), 2, "cancel leaked a KV slot");
}

#[test]
fn zero_max_new_round_trips_on_both_protocols() {
    // `GEN 0 <prompt>` over the wire: v2 answers ACK then END with no
    // TOK frames in between; a v1 connection gets the one-shot OK reply
    // with an empty completion. Neither hangs the decode loop.
    let (addr, handle) = spawn_stub_server(StubSessionEngine::new(2), 2);
    {
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        send_line(&mut conn, "HELLO v2");
        assert_eq!(read_line(&mut reader), "HELLO v2");
        send_line(&mut conn, "GEN 0 measure my prefill");
        let ack = read_line(&mut reader);
        let id: u64 = ack
            .strip_prefix("ACK ")
            .unwrap_or_else(|| panic!("expected ACK, got {ack:?}"))
            .parse()
            .unwrap();
        let frame = read_line(&mut reader);
        let rest = frame
            .strip_prefix("END ")
            .unwrap_or_else(|| panic!("expected END with no TOK frames, got {frame:?}"));
        let mut parts = rest.split(' ');
        assert_eq!(parts.next().unwrap().parse::<u64>().unwrap(), id);
        for ms in parts {
            assert!(ms.parse::<f64>().unwrap() >= 0.0);
        }
    }
    {
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        send_line(&mut conn, "GEN 0 hello");
        let reply = read_line(&mut reader);
        let mut parts = reply.splitn(6, ' ');
        assert_eq!(parts.next(), Some("OK"));
        let _id: u64 = parts.next().unwrap().parse().unwrap();
        for _ in 0..3 {
            let _ms: f64 = parts.next().unwrap().parse().unwrap();
        }
        assert_eq!(parts.next().unwrap_or(""), "", "v1 completion not empty");
    }
    handle.join().unwrap();
}

#[test]
fn v2_parse_errors_carry_stable_codes() {
    let (addr, handle) = spawn_stub_server(StubSessionEngine::new(1), 1);
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    send_line(&mut conn, "HELLO v2");
    assert_eq!(read_line(&mut reader), "HELLO v2");
    send_line(&mut conn, "NONSENSE");
    assert_eq!(read_line(&mut reader), "ERR 11 0 expected GEN or STATS");
    send_line(&mut conn, "GEN 8");
    assert_eq!(read_line(&mut reader), "ERR 15 0 empty prompt");
    send_line(&mut conn, "CANCEL nope");
    assert_eq!(read_line(&mut reader), "ERR 16 0 bad id");
    send_line(&mut conn, "HELLO v9");
    assert_eq!(
        read_line(&mut reader),
        "ERR 17 0 unsupported protocol version"
    );
    // Unblock the server's max-requests bound.
    send_line(&mut conn, "GEN 2 bye");
    let _ack = read_line(&mut reader);
    let mut saw_end = false;
    while !saw_end {
        saw_end = read_line(&mut reader).starts_with("END ");
    }
    handle.join().unwrap();
}

#[test]
fn half_open_connections_are_reaped_while_live_clients_stay_served() {
    let (addr_tx, addr_rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        server::serve_with_opts(
            StubSessionEngine::new(1),
            "127.0.0.1:0",
            Some(1),
            Some(Duration::from_millis(250)),
            move |a| {
                let _ = addr_tx.send(a);
            },
        )
        .unwrap()
    });
    let addr = addr_rx.recv().unwrap();

    // A half-open client: connects, dribbles a partial line (no
    // newline), then stalls forever. The reaper must close the socket
    // without waiting for the line to complete — before this test's
    // generous read timeout, and without the server shutting down.
    let mut staller = TcpStream::connect(addr).unwrap();
    staller.write_all(b"GEN 5 never finished").unwrap();
    staller
        .set_read_timeout(Some(Duration::from_secs(20)))
        .unwrap();
    let mut sink = Vec::new();
    let n = std::io::Read::read_to_end(&mut staller, &mut sink)
        .expect("reaper should close the stalled socket, not strand it");
    assert_eq!(n, 0, "reaped connection produced bytes: {sink:?}");

    // The server is still up: a live client gets a normal v1 reply
    // (this also consumes the max-requests bound and shuts it down).
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    send_line(&mut conn, "GEN 3 hello world");
    let reply = read_line(&mut reader);
    assert!(reply.starts_with("OK "), "live client got {reply:?}");
    handle.join().unwrap();
}
