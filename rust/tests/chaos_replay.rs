//! Chaos tier — runs WITHOUT `make artifacts`.
//!
//! Replays the PR-5 preemption traces through a scheduler whose engine
//! parks KV state in the *real* tiered [`KvStore`] behind a seeded
//! [`FaultyBackend`]: transient read/write errors, torn writes, silent
//! single-bit corruption, and latency spikes, all on one deterministic
//! schedule per seed. The self-healing contract under fire:
//!
//! - **zero `Failed` outcomes** — every injected fault is absorbed by
//!   retry, DRAM fallback, or recompute-from-prompt recovery;
//! - **byte-equality** — every request's tokens equal the fault-free
//!   sequential reference, recovered sessions included;
//! - **no leaks** — every KV slot and spill ticket is accounted for
//!   when the trace drains;
//! - **exact replay** — the same seed yields the same bytes and the
//!   same injected-fault counters, twice.
//!
//! Extra seeds come from the `CHAOS_SEED` env var (CI runs the tier
//! under several). The prefix-corruption and degraded-mode tests pin
//! the remaining rungs of the degradation ladder.

use anyhow::Result;
use m2cache::carbon::find_gpu;
use m2cache::coordinator::workload::{generate, Mix, TraceEvent, TraceSpec};
use m2cache::coordinator::{
    DecodeSession, FaultConfig, Fleet, FleetConfig, HandoffRecord, KvStore, KvTicket, Outcome,
    PhaseCost, PrefixConfig, PrefixCostModel, Request, SchedConfig, Scheduler, SessionEngine,
    SessionEvent, SpillTier, TieredPrefixCache,
};
use m2cache::telemetry::FaultCounters;
use std::collections::HashMap;

const VOCAB: usize = 97;
/// KV geometry of the chaos engine: positions per slot and values per
/// token per layer plane. Small on purpose — spill records stay cheap
/// while every byte still travels through the checksummed format.
const MAX_POS: usize = 64;
const D: usize = 2;

/// Deterministic engine over the real tiered store: next token is a
/// pure function of the fed token and position (so any correct
/// scheduler reproduces the same bytes regardless of interleaving),
/// while every forward writes a KV row and every park/restore moves
/// real bytes through the fault-injected backend.
struct ChaosEngine {
    kv: KvStore,
}

impl ChaosEngine {
    fn new(slots: usize, faults: FaultConfig) -> ChaosEngine {
        // DRAM budget 0: every clean park exercises the SSD record
        // path; the degradation ladder may still fall back to DRAM.
        ChaosEngine {
            kv: KvStore::new(slots, 2, MAX_POS * D, 0)
                .with_faults(faults)
                .with_retry(3, 0),
        }
    }
}

impl SessionEngine for ChaosEngine {
    fn capacity(&self) -> usize {
        self.kv.capacity()
    }

    fn open(&mut self, req: Request) -> Result<DecodeSession> {
        anyhow::ensure!(!req.prompt.is_empty(), "empty prompt");
        let slot = self
            .kv
            .acquire()
            .ok_or_else(|| anyhow::anyhow!("kv pool exhausted"))?;
        Ok(DecodeSession::new(req, slot))
    }

    fn forward(&mut self, s: &DecodeSession, token: u32) -> Result<Vec<f32>> {
        // A real KV write per forward, so parked state is never
        // trivially zero and corruption has something to corrupt.
        let pos = s.pos() % MAX_POS;
        let val = token as f32 + s.pos() as f32 * 0.5;
        self.kv
            .write_token(s.slot(), s.pos() % 2, pos, D, &[val; D], &[-val; D]);
        let mut logits = vec![0.0f32; VOCAB];
        logits[((token as usize).wrapping_mul(31) + s.pos() * 7 + 1) % VOCAB] = 1.0;
        Ok(logits)
    }

    fn close(&mut self, s: &mut DecodeSession) {
        self.kv.release(s.slot());
    }

    fn supports_spill(&self) -> bool {
        true
    }

    fn spill(&mut self, s: &DecodeSession) -> Result<KvTicket> {
        self.kv.spill(s.slot())
    }

    fn restore(&mut self, s: &mut DecodeSession, ticket: KvTicket) -> Result<()> {
        let slot = self.kv.restore(ticket)?;
        s.rebind_slot(slot);
        Ok(())
    }

    fn discard(&mut self, _s: &mut DecodeSession, ticket: KvTicket) {
        self.kv.discard(ticket);
    }

    fn begin_restore(&mut self, ticket: KvTicket) {
        // Overlapped-restore hint: prefetch the spill record through
        // the same fault-injected backend the demand path uses.
        self.kv.begin_restore(ticket);
    }

    fn supports_handoff(&self) -> bool {
        true
    }

    fn export_kv(&mut self, s: &mut DecodeSession) -> Result<HandoffRecord> {
        // The engine wraps KV rows at MAX_POS, so the record carries at
        // most one slot's worth of values.
        let used = s.pos().min(MAX_POS) * D;
        let ticket = self.kv.park_prefix_copy(s.slot(), used)?;
        let bytes = match self.kv.export_record(ticket) {
            Ok(b) => b,
            Err(e) => {
                self.kv.discard(ticket);
                return Err(e);
            }
        };
        self.kv.release(s.slot());
        Ok(HandoffRecord {
            session_id: s.id,
            used: s.pos(),
            kv_bytes: bytes.len() as u64,
            bytes,
        })
    }

    fn import_kv(&mut self, s: &mut DecodeSession, rec: &HandoffRecord) -> Result<()> {
        anyhow::ensure!(rec.session_id == s.id, "handoff record for wrong session");
        let ticket = self.kv.import_record(&rec.bytes)?;
        match self.kv.restore(ticket) {
            Ok(slot) => {
                s.rebind_slot(slot);
                Ok(())
            }
            Err(e) => {
                self.kv.discard(ticket);
                Err(e)
            }
        }
    }
}

fn spec(n: usize) -> TraceSpec {
    TraceSpec {
        mix: Mix::AdversarialLongPrompt,
        n,
        seed: 0x7ACE,
        vocab: VOCAB as u32,
    }
}

/// Reference: every request alone on a fault-free engine.
fn sequential_reference(events: &[TraceEvent]) -> HashMap<u64, Vec<u32>> {
    let mut eng = ChaosEngine::new(1, FaultConfig::default());
    let mut tokens = HashMap::new();
    for ev in events {
        let mut s = eng.open(ev.to_request()).unwrap();
        while !s.is_done() {
            s.step(&mut eng).unwrap();
        }
        eng.close(&mut s);
        tokens.insert(ev.id, s.generated);
    }
    tokens
}

/// The base chaos seeds CI sweeps, plus whatever `CHAOS_SEED` adds.
fn chaos_seeds() -> Vec<u64> {
    let mut seeds = vec![0xC4A0_51, 0xC4A0_52, 0xC4A0_53];
    if let Ok(s) = std::env::var("CHAOS_SEED") {
        if let Ok(v) = s.parse::<u64>() {
            if !seeds.contains(&v) {
                seeds.push(v);
            }
        }
    }
    seeds
}

fn chaos_faults(seed: u64) -> FaultConfig {
    FaultConfig {
        seed,
        read_error: 0.25,
        write_error: 0.25,
        torn_write: 0.15,
        bit_flip: 0.10,
        latency_spike: 0.5,
        spike_ms: 0, // count spikes, keep the clock virtual
    }
}

/// What one chaos replay observed.
struct ChaosRun {
    tokens: HashMap<u64, Vec<u32>>,
    recovered_events: u64,
    preemptions: u64,
    resumes: u64,
    recoveries: u64,
    faults: FaultCounters,
}

/// Drive a trace to idle under 2x oversubscription with the given
/// fault schedule. Panics on any `Failed` outcome; asserts no slot or
/// ticket leaks once the trace drains.
fn chaos_replay(events: &[TraceEvent], faults: FaultConfig, cfg: SchedConfig) -> ChaosRun {
    const SLOTS: usize = 2;
    let mut sched = Scheduler::with_config(ChaosEngine::new(SLOTS, faults), 2 * SLOTS, cfg);
    sched.set_virtual_now_ms(0);
    let mut now = 0u64;
    let mut next_ev = 0usize;
    let mut tokens: HashMap<u64, Vec<u32>> = HashMap::new();
    let mut recovered_events = 0u64;
    loop {
        while next_ev < events.len() && events[next_ev].at_ms <= now {
            sched.submit(events[next_ev].to_request());
            next_ev += 1;
        }
        if sched.is_idle() {
            if next_ev >= events.len() {
                break;
            }
            now = events[next_ev].at_ms;
            sched.set_virtual_now_ms(now);
            continue;
        }
        let r = sched.tick();
        now += r.steps_run as u64;
        sched.set_virtual_now_ms(now);
        for ev in &r.events {
            if matches!(ev, SessionEvent::Recovered { .. }) {
                recovered_events += 1;
            }
        }
        for o in r.outcomes {
            match o {
                Outcome::Done(c) => {
                    tokens.insert(c.response.id, c.response.tokens);
                }
                Outcome::Failed { id, error } => {
                    panic!("degradation ladder leaked a failure: req {id}: {error}")
                }
            }
        }
    }
    assert_eq!(sched.engine().kv.in_use(), 0, "leaked KV slots");
    assert_eq!(sched.engine().kv.spilled(), 0, "leaked spill tickets");
    ChaosRun {
        tokens,
        recovered_events,
        preemptions: sched.preemptions,
        resumes: sched.resumes,
        recoveries: sched.recoveries,
        faults: sched.engine().kv.fault_counters(),
    }
}

#[test]
fn chaos_schedules_preserve_bytes_and_leak_nothing() {
    let events = generate(&spec(40));
    let reference = sequential_reference(&events);
    let mut injected_total = 0u64;
    for seed in chaos_seeds() {
        let run = chaos_replay(&events, chaos_faults(seed), SchedConfig::default());
        assert_eq!(
            run.tokens.len(),
            events.len(),
            "seed {seed:#x}: lost requests"
        );
        for (id, toks) in &run.tokens {
            assert_eq!(
                toks, &reference[id],
                "seed {seed:#x}: request {id} bytes diverged under faults"
            );
        }
        assert!(run.preemptions > 0, "seed {seed:#x}: trace never preempted");
        // Every preemption settles exactly one way: a clean restore or
        // a recompute-from-prompt recovery.
        assert_eq!(
            run.preemptions,
            run.resumes + run.recoveries,
            "seed {seed:#x}: preemptions must pair with resumes + recoveries"
        );
        assert_eq!(
            run.recovered_events, run.recoveries,
            "seed {seed:#x}: Recovered events disagree with the counter"
        );
        injected_total += run.faults.injected();
        // Exact replay: the same seed reproduces bytes, recovery
        // decisions, and the injected-fault schedule bit-for-bit.
        let again = chaos_replay(&events, chaos_faults(seed), SchedConfig::default());
        assert_eq!(again.tokens, run.tokens, "seed {seed:#x}: bytes not replayable");
        assert_eq!(again.recoveries, run.recoveries, "seed {seed:#x}");
        assert_eq!(again.faults, run.faults, "seed {seed:#x}: fault schedule drifted");
    }
    assert!(
        injected_total > 0,
        "chaos seeds injected nothing — the tier is vacuous"
    );
}

#[test]
fn pipelined_chaos_replay_composes_overlap_with_fault_injection() {
    // The pipelined datapath under fire: `overlap_restore` prefetches
    // spill records through the same FaultyBackend the demand path
    // uses — synchronously at hint time, because deterministic
    // decorators refuse the async seam so every RNG draw stays in
    // program order. Injected corruption can therefore land in the
    // prefetch buffer itself; the CRC check at redemption must then
    // route the restore back through the demand path and its ladder.
    // Contract: zero Failed outcomes, reference bytes, no leaked
    // slots or tickets, and bit-exact replay per seed.
    let events = generate(&spec(40));
    let reference = sequential_reference(&events);
    let overlap = SchedConfig {
        overlap_restore: true,
        ..SchedConfig::default()
    };
    let mut injected_total = 0u64;
    for seed in chaos_seeds() {
        let run = chaos_replay(&events, chaos_faults(seed), overlap);
        assert_eq!(
            run.tokens.len(),
            events.len(),
            "seed {seed:#x}: lost requests"
        );
        for (id, toks) in &run.tokens {
            assert_eq!(
                toks, &reference[id],
                "seed {seed:#x}: request {id} diverged under overlapped faults"
            );
        }
        assert!(run.preemptions > 0, "seed {seed:#x}: trace never preempted");
        assert_eq!(
            run.preemptions,
            run.resumes + run.recoveries,
            "seed {seed:#x}: preemptions must pair with resumes + recoveries"
        );
        injected_total += run.faults.injected();
        let again = chaos_replay(&events, chaos_faults(seed), overlap);
        assert_eq!(
            again.tokens, run.tokens,
            "seed {seed:#x}: overlapped bytes not replayable"
        );
        assert_eq!(
            again.faults, run.faults,
            "seed {seed:#x}: overlapped fault schedule drifted"
        );
    }
    assert!(
        injected_total > 0,
        "overlapped chaos seeds injected nothing — the leg is vacuous"
    );
}

#[test]
fn all_restores_corrupt_forces_recompute_for_every_preemption() {
    // bit_flip 1.0: every spill record lands silently corrupt, so every
    // restore must fail the CRC check and climb the ladder to
    // recompute-from-prompt — deterministically, whatever the RNG does.
    let events = generate(&spec(40));
    let reference = sequential_reference(&events);
    let faults = FaultConfig {
        bit_flip: 1.0,
        ..FaultConfig::default()
    };
    let run = chaos_replay(&events, faults, SchedConfig::default());
    assert!(run.preemptions > 0, "trace never preempted");
    assert_eq!(run.resumes, 0, "a corrupt record restored");
    assert_eq!(run.recoveries, run.preemptions);
    assert!(run.faults.crc_failures >= run.preemptions);
    for (id, toks) in &run.tokens {
        assert_eq!(toks, &reference[id], "recovered request {id} diverged");
    }
}

#[test]
fn corrupt_prefix_cache_entry_is_invalidated_with_cold_prefill_fallback() {
    // hot_slots 0 + DRAM budget 0: the insert parks straight to the
    // SSD file; bit_flip 1.0 corrupts the record in flight.
    let faults = FaultConfig {
        bit_flip: 1.0,
        ..FaultConfig::default()
    };
    let mut kv = KvStore::new(4, 2, 8 * D, 0).with_faults(faults).with_retry(1, 0);
    let mut pc = TieredPrefixCache::new(PrefixConfig {
        max_entries: 8,
        min_depth: 1,
        hot_slots: 0,
        promote_hits: 2,
        vals_per_token: D,
        cost: PrefixCostModel::default(),
    });
    let prompt = [5, 1, 4, 1];
    let src = kv.acquire().unwrap();
    for (pos, &t) in prompt.iter().enumerate() {
        for layer in 0..2 {
            let base = t as f32 * 10.0 + layer as f32;
            kv.write_token(src, layer, pos, D, &[base, base + 0.5], &[-base, -base - 0.5]);
        }
    }
    pc.insert(&mut kv, &prompt, src);
    kv.release(src);
    assert_eq!(pc.len(), 1);
    assert_eq!(kv.ssd_parked(), 1, "insert must park to the SSD file");
    // Attach must catch the flipped bit via the record CRC, drop the
    // entry, and report a miss — the caller cold-prefills instead of
    // consuming corrupt rows.
    let dst = kv.acquire().unwrap();
    assert!(pc.attach(&mut kv, &prompt, dst).is_none());
    let stats = *pc.stats();
    assert_eq!(stats.invalidated, 1);
    assert_eq!(stats.misses, 1);
    assert_eq!(stats.hits, 0);
    assert_eq!(pc.len(), 0, "broken entry must leave the index");
    assert!(kv.fault_counters().crc_failures >= 1);
    assert_eq!(kv.spilled(), 0, "invalidated entry leaked its ticket");
    // The next lookup is a plain miss: no poisoned state left behind.
    assert!(pc.attach(&mut kv, &prompt, dst).is_none());
    assert_eq!(pc.stats().invalidated, 1, "double invalidation");
    kv.release(dst);
    pc.drain(&mut kv);
    assert_eq!((kv.pins(), kv.spilled(), kv.in_use()), (0, 0, 0));
}

#[test]
fn persistent_write_failure_degrades_to_dram_only_spill() {
    // Every SSD write errors before any byte lands. Three exhausted
    // spills in a row flip the store into DRAM-only mode; later parks
    // go straight to DRAM without touching the file, and everything
    // still round-trips.
    let faults = FaultConfig {
        write_error: 1.0,
        ..FaultConfig::default()
    };
    let mut kv = KvStore::new(4, 2, 8 * D, 0).with_faults(faults).with_retry(2, 0);
    let mut tickets = Vec::new();
    for i in 0..4u64 {
        let s = kv.acquire().expect("pool has room");
        let val = (i + 1) as f32;
        kv.write_token(s, 0, 0, D, &[val; D], &[-val; D]);
        let t = kv.spill(s).expect("spill must degrade, not fail");
        assert_eq!(kv.ticket_tier(t), Some(SpillTier::Dram));
        tickets.push((t, val));
        let f = kv.fault_counters();
        if i < 3 {
            assert_eq!(f.degraded_spills, i + 1);
            assert_eq!(f.ssd_degraded, i == 2, "streak flips at the third exhaustion");
        } else {
            // Degraded mode: the fourth park never touched the file.
            assert_eq!(f.degraded_spills, 3);
            assert_eq!(f.injected_write_errors, 3 * 2, "retry budget is 2 attempts");
            assert!(f.ssd_degraded);
        }
    }
    assert!(kv.ssd_degraded());
    assert_eq!(kv.ssd_parked(), 0);
    for (t, val) in tickets {
        let s = kv.restore(t).expect("DRAM fallback restores cleanly");
        assert_eq!(&kv.k_layer(s, 0)[..D], &[val; D]);
        kv.release(s);
    }
    assert_eq!(kv.spilled(), 0);
}

#[test]
fn fleet_handoff_under_corruption_recovers_by_recompute_and_never_fails() {
    // Replica 0 flips a bit in every spill record it writes (DRAM
    // budget 0, so parks go through the SSD path), which poisons
    // handoffs in BOTH directions: records exported from 0 ship the
    // corruption to the peer (whose import CRC-rejects them before
    // admitting any bytes), and clean records imported INTO 0 corrupt
    // at park time so the post-import restore CRC-fails. Either way
    // the fleet's recovery ladder must fire — recompute-from-prompt on
    // the destination — and the trace must finish with reference bytes
    // and zero leaked slots or tickets, never a failed session.
    let events = generate(&TraceSpec {
        mix: Mix::DecodeHeavy,
        n: 10,
        seed: 0xF1E7,
        vocab: VOCAB as u32,
    });
    let reference = sequential_reference(&events);
    let mut fleet = Fleet::new(FleetConfig {
        force_handoff: true,
        handoff_after: 1,
        min_remaining: 1,
        ..FleetConfig::default()
    });
    let a100 = find_gpu("A100").unwrap();
    let m40 = find_gpu("M40").unwrap();
    let flip = FaultConfig {
        bit_flip: 1.0,
        ..FaultConfig::default()
    };
    fleet.add_replica(ChaosEngine::new(10, flip), a100, PhaseCost::uniform(1.0));
    fleet.add_replica(
        ChaosEngine::new(10, FaultConfig::default()),
        m40,
        PhaseCost::uniform(1.0),
    );
    let report = fleet
        .run_trace(&events)
        .expect("a faulted handoff must degrade, never fail the trace");
    assert!(
        report.counters.handoff_recoveries >= 1,
        "corruption never tripped a recovery: {:?}",
        report.counters
    );
    let got = fleet.outputs();
    assert_eq!(got.len(), events.len(), "lost requests");
    for (id, toks) in &got {
        assert_eq!(toks, &reference[id], "request {id} diverged under faulted handoff");
    }
    for r in 0..2 {
        assert_eq!(fleet.engine(r).kv.in_use(), 0, "replica {r} leaked KV slots");
        assert_eq!(fleet.engine(r).kv.spilled(), 0, "replica {r} leaked tickets");
    }
    // Every recovery traces back to a CRC rejection somewhere in the
    // two stores — recompute is a response to detected corruption, not
    // a spurious slow path.
    let crc: u64 = (0..2).map(|r| fleet.engine(r).kv.fault_counters().crc_failures).sum();
    assert!(crc >= report.counters.handoff_recoveries, "recoveries without CRC rejections");
}
