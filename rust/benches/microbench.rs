//! `cargo bench --bench microbench` — hot-path microbenchmarks for the
//! L3 coordinator (the §Perf working set): ATU reconciliation, top-k
//! selection, predictor scoring, quantization codecs, f16 conversion,
//! transfer-cost model, and the executed engine's per-token step.
//! Built on the from-scratch `util::bench` harness (criterion is
//! unavailable offline).

use m2cache::cache::{AtuPolicy, CacheUnit, HbmPolicy};
use m2cache::coordinator::{tokenize, EngineConfig, ExecEngine};
use m2cache::memsim::{HardwareSpec, Link};
use m2cache::model::weights::PredictorWeights;
use m2cache::precision::plan::{plan_from_scores, PrecisionRatios};
use m2cache::precision::{f16, quant};
use m2cache::sparsity;
use m2cache::util::bench::{fmt_dur, Bench, Table};
use m2cache::util::rng::Rng;

fn main() {
    let b = Bench::default();
    let mut t = Table::new(["bench", "mean", "p50", "p99", "throughput"]);
    let mut rng = Rng::new(7);

    // --- ATU reconciliation over a 13B-sized layer (n=13824, 20% active)
    {
        let n = 13824usize;
        let active = n / 5;
        let mut unit = CacheUnit::meta_only(active);
        let mut policy = AtuPolicy;
        let ratios = PrecisionRatios::new(0.05, 0.05, 0.10);
        let mut scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let plan0 = plan_from_scores(&scores, &ratios);
        for na in policy.update(&mut unit, &plan0).load {
            unit.insert(na.neuron, na.dtype, &[]);
        }
        let stats = b.run(|| {
            // Perturb 20% of scores (token churn), replan, reconcile.
            for _ in 0..n / 5 {
                let i = rng.range(0, n);
                scores[i] = rng.f32();
            }
            let plan = plan_from_scores(&scores, &ratios);
            let upd = policy.update(&mut unit, &plan);
            for na in &upd.load {
                unit.insert(na.neuron, na.dtype, &[]);
            }
            upd.hits
        });
        t.row([
            "atu_reconcile_13b_layer".into(),
            fmt_dur(stats.mean),
            fmt_dur(stats.p50),
            fmt_dur(stats.p99),
            format!("{:.0} plans/s", stats.throughput(1.0)),
        ]);
    }

    // --- top-k over 28672 scores (70B layer width)
    {
        let scores: Vec<f32> = (0..28672).map(|_| rng.f32()).collect();
        let stats = b.run(|| sparsity::top_k(&scores, 5734));
        t.row([
            "topk_70b_layer".into(),
            fmt_dur(stats.mean),
            fmt_dur(stats.p50),
            fmt_dur(stats.p99),
            format!("{:.1} M scores/s", 28672.0 * stats.throughput(1.0) / 1e6),
        ]);
    }

    // --- native predictor scoring (tiny-model geometry)
    {
        let (d, r, n) = (128usize, 32usize, 512usize);
        let pred = PredictorWeights {
            a: (0..d * r).map(|_| rng.f32()).collect(),
            b: (0..r * n).map(|_| rng.f32()).collect(),
            rank: r,
        };
        let x: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
        let mut out = Vec::new();
        let stats = b.run(|| {
            sparsity::score(&pred, &x, &mut out);
            out.len()
        });
        t.row([
            "predictor_score_tiny".into(),
            fmt_dur(stats.mean),
            fmt_dur(stats.p50),
            fmt_dur(stats.p99),
            format!("{:.2} M scores/s", n as f64 * stats.throughput(1.0) / 1e6),
        ]);
    }

    // --- quantization codecs over one neuron record (3*4096 values, 7B)
    {
        let vals: Vec<f32> = (0..3 * 4096).map(|_| rng.f32() - 0.5).collect();
        let stats = b.run(|| quant::quantize_int8(&vals));
        t.row([
            "quantize_int8_neuron_7b".into(),
            fmt_dur(stats.mean),
            fmt_dur(stats.p50),
            fmt_dur(stats.p99),
            format!(
                "{:.2} GB/s",
                4.0 * vals.len() as f64 * stats.throughput(1.0) / 1e9
            ),
        ]);
        let block = quant::quantize_int4(&vals, 64);
        let mut out = Vec::new();
        let stats = b.run(|| {
            out.clear();
            quant::dequantize_int4(&block, &mut out);
            out.len()
        });
        t.row([
            "dequantize_int4_neuron_7b".into(),
            fmt_dur(stats.mean),
            fmt_dur(stats.p50),
            fmt_dur(stats.p99),
            format!(
                "{:.2} M vals/s",
                vals.len() as f64 * stats.throughput(1.0) / 1e6
            ),
        ]);
    }

    // --- f16 batch decode (gather path)
    {
        let vals: Vec<f32> = (0..3 * 4096).map(|_| rng.f32() - 0.5).collect();
        let mut bytes = Vec::new();
        f16::encode_slice(&vals, &mut bytes);
        let mut out = Vec::new();
        let stats = b.run(|| {
            out.clear();
            f16::decode_slice(&bytes, &mut out);
            out.len()
        });
        t.row([
            "f16_decode_neuron_7b".into(),
            fmt_dur(stats.mean),
            fmt_dur(stats.p50),
            fmt_dur(stats.p99),
            format!(
                "{:.2} M vals/s",
                vals.len() as f64 * stats.throughput(1.0) / 1e6
            ),
        ]);
    }

    // --- transfer cost model evaluation (hot in the sim engine loop)
    {
        let hw = HardwareSpec::rtx3090_testbed();
        let stats = b.run(|| {
            let mut acc = 0.0f64;
            for i in 0..100u64 {
                acc += hw.links.get(Link::DramToHbm).time_s(4096 * (i + 1));
            }
            acc
        });
        t.row([
            "xfer_cost_model_x100".into(),
            fmt_dur(stats.mean),
            fmt_dur(stats.p50),
            fmt_dur(stats.p99),
            format!("{:.1} M evals/s", 100.0 * stats.throughput(1.0) / 1e6),
        ]);
    }

    // --- executed per-token step (full PJRT path, needs artifacts)
    if std::path::Path::new("artifacts/layer_step.hlo.txt").exists() {
        let mut eng =
            ExecEngine::new(std::path::Path::new("artifacts"), EngineConfig::full())
                .expect("engine");
        let prompt = tokenize("the quick brown fox ");
        eng.generate(&prompt, 4).expect("warmup");
        let quick = Bench::quick();
        eng.reset();
        let stats = quick.run(|| {
            if eng.pos() + 1 >= eng.max_seq() {
                eng.reset();
            }
            eng.feed(b't' as u32).expect("feed")
        });
        t.row([
            "exec_engine_token_step".into(),
            fmt_dur(stats.mean),
            fmt_dur(stats.p50),
            fmt_dur(stats.p99),
            format!("{:.1} tok/s", stats.throughput(1.0)),
        ]);
    }

    println!("== M2Cache L3 microbenchmarks ==\n");
    t.print();
}
