//! `cargo bench --bench figures` — regenerates every table AND figure
//! of the paper's evaluation (quick-scale) and times each driver.
//! One bench section per paper artifact; the printed rows are the same
//! series the paper reports (see EXPERIMENTS.md for paper-vs-measured).

use m2cache::experiments::{self, ExpOpts};
use std::time::Instant;

fn main() {
    let opts = ExpOpts {
        quick: true,
        artifacts: "artifacts",
    };
    let mut failures = 0;
    println!("== M2Cache paper-figure bench suite (quick scale) ==\n");
    for id in experiments::ALL {
        let t0 = Instant::now();
        match experiments::run(id, opts) {
            Ok(out) => {
                println!(
                    "──────────────────────────── {id} ({:.2}s)",
                    t0.elapsed().as_secs_f64()
                );
                println!("{out}");
            }
            Err(e) => {
                println!("──────────────────────────── {id}: SKIPPED ({e:#})\n");
                if !format!("{e:#}").contains("artifacts") {
                    failures += 1;
                }
            }
        }
    }
    if failures > 0 {
        eprintln!("{failures} experiments failed");
        std::process::exit(1);
    }
}
